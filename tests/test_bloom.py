"""Unit + property tests for the double-Bloom hit/miss predictor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import bloom


def test_empty_filter_predicts_miss():
    s = bloom.make_state(num_sets=4, associativity=8)
    hit, s = bloom.predict(s, jnp.int32(0), jnp.uint32(123))
    assert not bool(hit)
    assert int(s.queries) == 1 and int(s.predicted_hits) == 0


def test_inserted_tag_predicts_hit():
    s = bloom.make_state(num_sets=4, associativity=8)
    s = bloom.record_access(s, jnp.int32(2), jnp.uint32(77))
    hit, _ = bloom.predict(s, jnp.int32(2), jnp.uint32(77))
    assert bool(hit)
    # other sets are unaffected
    hit_other, _ = bloom.predict(s, jnp.int32(1), jnp.uint32(77))
    assert not bool(hit_other)


def test_swap_happens_at_associativity():
    assoc = 4
    s = bloom.make_state(num_sets=1, associativity=assoc)
    for t in range(assoc):
        s = bloom.record_access(s, jnp.int32(0), jnp.uint32(t))
    assert int(s.swaps) == 1
    assert int(s.n_mru[0]) == 0  # reset after swap


def test_post_swap_still_no_false_negative_for_mru():
    """After a swap, the new BF1 (= old BF2) must contain the blocks that
    are still resident (the n MRU ones)."""
    assoc = 4
    s = bloom.make_state(num_sets=1, associativity=assoc)
    tags = [10, 20, 30, 40]   # exactly assoc distinct tags -> triggers swap
    for t in tags:
        s = bloom.record_access(s, jnp.int32(0), jnp.uint32(t))
    for t in tags:            # all remain predicted-hit after the swap
        hit, s = bloom.predict(s, jnp.int32(0), jnp.uint32(t))
        assert bool(hit), f"false negative for tag {t} after swap"


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2**20), min_size=1,
                max_size=64),
       st.integers(min_value=2, max_value=16))
def test_property_no_false_negatives(tags, assoc):
    """THE paper invariant: any tag inserted since the last point at which
    it could have been evicted must be predicted hit.  We model an LRU set
    alongside and check every resident tag is predicted hit."""
    s = bloom.make_state(num_sets=1, associativity=assoc)
    resident: list[int] = []  # LRU order, most recent last
    for t in tags:
        if t in resident:
            resident.remove(t)
        resident.append(t)
        resident = resident[-assoc:]
        s = bloom.record_access(s, jnp.int32(0), jnp.uint32(t))
        for r in resident:
            hit, s = bloom.predict(s, jnp.int32(0), jnp.uint32(r))
            assert bool(hit), (
                f"false negative: resident tag {r} predicted miss")


def test_false_positive_rate_reasonable():
    """32-B filters at assoc=32 should stay well under ~35% FP (paper shows
    No-Prediction costs 9%; Bloom ~= Perfect within 1%)."""
    rng = np.random.default_rng(0)
    s = bloom.make_state(num_sets=1, associativity=32)
    inserted = rng.choice(2**24, size=32, replace=False)
    for t in inserted:
        s = bloom.record_access(s, jnp.int32(0), jnp.uint32(int(t)))
    probes = rng.choice(2**24, size=400, replace=False)
    probes = [p for p in probes if p not in set(inserted.tolist())]
    fp = 0
    for p in probes:
        hit, s = bloom.predict(s, jnp.int32(0), jnp.uint32(int(p)))
        fp += int(bool(hit))
    rate = fp / len(probes)
    analytic = bloom.false_positive_rate(32, 32)
    assert rate < max(3 * analytic, 0.35), (rate, analytic)


def test_analytic_fp_rate_monotone():
    assert bloom.false_positive_rate(32, 8) < bloom.false_positive_rate(32, 64)
    assert bloom.false_positive_rate(64, 32) < bloom.false_positive_rate(32, 32)


# ------------------------------------------------- counting-BF ablation

def test_counting_bloom_no_false_negatives_and_removal():
    """Footnote-2 alternative: residency tracking is exact under
    insert/remove (no swap machinery needed), at 4x the bits."""
    import numpy as np
    from repro.core import bloom as B
    r = np.random.default_rng(3)
    st = B.make_counting_state(1, filter_bytes=128)   # 4x a 32B filter
    resident = set()
    for _ in range(400):
        tag = int(r.integers(0, 1 << 20))
        if tag in resident or (r.random() < 0.6 and len(resident) < 32):
            if tag not in resident:
                st = B.counting_insert(st, 0, jnp.uint32(tag))
                resident.add(tag)
        elif resident and r.random() < 0.5:
            victim = next(iter(resident))
            st = B.counting_remove(st, 0, jnp.uint32(victim))
            resident.discard(victim)
        # invariant: every resident tag must test positive
        for t in list(resident)[:8]:
            assert bool(B.counting_query(st, 0, jnp.uint32(t))), t


def test_counting_bloom_fp_rate_vs_double_filter():
    """The trade the paper names: a counting filter with the SAME byte
    budget as ONE plain filter (i.e. 1/4 the cells of BF1+BF2 combined)
    produces a worse false-positive rate; with 4x bytes it wins by
    tracking residency exactly.  This quantifies footnote 2."""
    import numpy as np
    from repro.core import bloom as B
    r = np.random.default_rng(4)
    ways = 16
    universe = [int(x) for x in r.integers(0, 1 << 22, 2000)]
    resident = universe[:ways]

    def fp_rate(filter_bytes):
        st = B.make_counting_state(1, filter_bytes=filter_bytes)
        # simulate heavy churn: 200 insert/remove cycles
        cur = list(resident)
        for t in cur:
            st = B.counting_insert(st, 0, jnp.uint32(t))
        for i in range(200):
            new = universe[ways + i]
            old = cur[i % ways]
            st = B.counting_remove(st, 0, jnp.uint32(old))
            st = B.counting_insert(st, 0, jnp.uint32(new))
            cur[i % ways] = new
        misses = [t for t in universe[500:1500] if t not in cur]
        fps = sum(bool(B.counting_query(st, 0, jnp.uint32(t)))
                  for t in misses)
        return fps / max(len(misses), 1)

    small, big = fp_rate(32), fp_rate(128)
    assert big <= small, (small, big)
    assert big < 0.35, f"4x-budget counting filter FP rate too high: {big}"
