"""Property tests for the engine invariants the autotuner leans on.

The fixed-grid cases in test_runtime.py / test_workloads.py pin these
for hand-picked epoch lengths and tenant layouts; here hypothesis draws
the trace, the epoch partition, the config (predictor x compression)
and the tenant masks, because the search layer (repro.autotune) visits
config/partition combinations no fixed grid anticipates:

  * resumability: any epoch partition of a trace, streamed through an
    explicit ``EngineState`` carry, accumulates integer Stats
    bit-identical to one monolithic dispatch;
  * per-tenant attribution: count-masked replays whose masks partition
    the request stream sum to the unmasked run's integer Stats exactly;
  * both at once (the fleet/churn path): masked epoch streaming.

Guarded by ``importorskip`` like tests/test_bloom.py so tier-1 passes
without the hypothesis package.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import address_separation as asep  # noqa: E402
from repro.core import controller as ctl  # noqa: E402
from repro.core import engine  # noqa: E402

# Small config family: tiny set counts keep each compile cheap; the
# drawn axes are the ones the autotuner overrides on real configs.
_PREDS = (ctl.Predictor.BLOOM, ctl.Predictor.NONE, ctl.Predictor.PERFECT)


def _cfg(pred: ctl.Predictor, comp: bool) -> ctl.MorpheusConfig:
    amap = asep.make_map(conv_sets=8, num_cache_chips=2, sets_per_chip=4)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4,
                              predictor=pred, compression=comp)


def _trace(n: int, span: int, seed: int):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, span, size=n).astype(np.uint32),
            rng.random(n) < 0.3,
            rng.integers(0, 3, size=n).astype(np.int32))


def _sum_rows(stats: ctl.Stats) -> ctl.Stats:
    return type(stats)(*[np.asarray(x).sum(axis=0) for x in stats])


def _assert_int_identical(a: ctl.Stats, b: ctl.Stats, ctx=""):
    for f in ctl.Stats._fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if f in ctl._INT_FIELDS:
            assert int(x) == int(y), f"{ctx} {f}: {x} vs {y}"
        else:
            tol = 1e-3 * max(abs(float(x)), 1.0)
            assert abs(float(x) - float(y)) <= tol, \
                f"{ctx} {f}: {x} vs {y}"


# A drawn scenario: trace shape/seed, config axes, partition cuts and
# tenant assignment all come from one strategy so every property sees
# the same distribution.  Lengths are drawn coarse (multiples of 100)
# to bound the number of distinct padded shapes XLA has to compile.
_scenario = st.fixed_dictionaries({
    "n": st.integers(6, 14).map(lambda k: k * 100),
    "span": st.sampled_from([512, 2048]),
    "seed": st.integers(0, 2 ** 16),
    "pred": st.sampled_from(_PREDS),
    "comp": st.booleans(),
    "cuts": st.lists(st.integers(1, 99), min_size=0, max_size=4,
                     unique=True),
    "n_tenants": st.integers(2, 4),
})


def _bounds(n: int, cuts) -> list:
    """Turn percentage cut points into epoch [start, end) bounds."""
    edges = sorted({0, n} | {max(1, min(n - 1, c * n // 100))
                             for c in cuts})
    return list(zip(edges[:-1], edges[1:]))


def _monolithic(cfg, trace, warmup) -> ctl.Stats:
    addrs, writes, levels = trace
    return engine.simulate_parallel(cfg, addrs, writes, levels, warmup)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(_scenario)
def test_epoch_partition_bit_identity(sc):
    """Any partition, streamed with pos0 offsets, == one dispatch."""
    cfg = _cfg(sc["pred"], sc["comp"])
    addrs, writes, levels = _trace(sc["n"], sc["span"], sc["seed"])
    warmup = sc["n"] // 4
    state = engine.init_state(cfg, 1)
    total = None
    for a, b in _bounds(sc["n"], sc["cuts"]):
        pt = engine.pack(cfg, [(addrs[a:b], writes[a:b], levels[a:b],
                                warmup)], pos0=[a])
        state, delta = engine.advance_packed(cfg, pt, state)
        delta = ctl.Stats(*[np.asarray(x)[0] for x in delta])
        total = delta if total is None else \
            ctl.Stats(*[x + y for x, y in zip(total, delta)])
    mono = _monolithic(cfg, (addrs, writes, levels), warmup)
    _assert_int_identical(total, mono,
                          f"partition {sc['cuts']} pred={sc['pred']}")


@settings(max_examples=10, deadline=None, derandomize=True)
@given(_scenario)
def test_tenant_masks_sum_to_global(sc):
    """Count-masked replays over a mask partition sum bit-identically."""
    cfg = _cfg(sc["pred"], sc["comp"])
    addrs, writes, levels = _trace(sc["n"], sc["span"], sc["seed"])
    warmup = sc["n"] // 4
    k = sc["n_tenants"]
    rng = np.random.default_rng(sc["seed"] + 1)
    tenant = rng.integers(0, k, size=sc["n"])
    masks = [tenant == t for t in range(k)]
    pt = engine.pack(cfg, [(addrs, writes, levels, warmup)] * k,
                     count=masks)
    per_tenant = engine._run_packed(cfg, pt, engine.resolve_backend(None))
    mono = _monolithic(cfg, (addrs, writes, levels), warmup)
    summed = _sum_rows(per_tenant)
    for f in ctl._INT_FIELDS:
        assert int(np.asarray(getattr(summed, f))) == \
            int(np.asarray(getattr(mono, f))), \
            f"{f}: masked sum != global (k={k}, pred={sc['pred']})"


@settings(max_examples=8, deadline=None, derandomize=True)
@given(_scenario)
def test_masked_epoch_streaming_sums_to_global(sc):
    """The fleet/churn path: per-tenant masks x epoch partition at once.

    K state rows advance through every epoch slice with count masks;
    the K x E deltas summed over both axes must equal the monolithic
    unmasked run on every integer counter.
    """
    cfg = _cfg(sc["pred"], sc["comp"])
    addrs, writes, levels = _trace(sc["n"], sc["span"], sc["seed"])
    warmup = sc["n"] // 4
    k = sc["n_tenants"]
    rng = np.random.default_rng(sc["seed"] + 2)
    tenant = rng.integers(0, k, size=sc["n"])
    state = engine.init_state(cfg, k)
    total = None
    for a, b in _bounds(sc["n"], sc["cuts"]):
        masks = [(tenant == t)[a:b] for t in range(k)]
        pt = engine.pack(cfg, [(addrs[a:b], writes[a:b], levels[a:b],
                                warmup)] * k, pos0=[a] * k, count=masks)
        state, delta = engine.advance_packed(cfg, pt, state)
        delta = _sum_rows(delta)
        total = delta if total is None else \
            ctl.Stats(*[x + y for x, y in zip(total, delta)])
    mono = _monolithic(cfg, (addrs, writes, levels), warmup)
    for f in ctl._INT_FIELDS:
        assert int(np.asarray(getattr(total, f))) == \
            int(np.asarray(getattr(mono, f))), \
            f"{f}: masked stream != global (k={k}, cuts={sc['cuts']})"
