"""Property tests for the engine invariants the autotuner leans on.

The fixed-grid cases in test_runtime.py / test_workloads.py pin these
for hand-picked epoch lengths and tenant layouts; here hypothesis draws
the trace, the epoch partition, the config (predictor x compression)
and the tenant masks, because the search layer (repro.autotune) visits
config/partition combinations no fixed grid anticipates:

  * resumability: any epoch partition of a trace, streamed through an
    explicit ``EngineState`` carry, accumulates integer Stats
    bit-identical to one monolithic dispatch;
  * per-tenant attribution: count-masked replays whose masks partition
    the request stream sum to the unmasked run's integer Stats exactly;
  * both at once (the fleet/churn path): masked epoch streaming.

Guarded by ``importorskip`` like tests/test_bloom.py so tier-1 passes
without the hypothesis package.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import address_separation as asep  # noqa: E402
from repro.core import controller as ctl  # noqa: E402
from repro.core import engine  # noqa: E402

# Small config family: tiny set counts keep each compile cheap; the
# drawn axes are the ones the autotuner overrides on real configs.
_PREDS = (ctl.Predictor.BLOOM, ctl.Predictor.NONE, ctl.Predictor.PERFECT)


def _cfg(pred: ctl.Predictor, comp: bool) -> ctl.MorpheusConfig:
    amap = asep.make_map(conv_sets=8, num_cache_chips=2, sets_per_chip=4)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4,
                              predictor=pred, compression=comp)


def _trace(n: int, span: int, seed: int):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, span, size=n).astype(np.uint32),
            rng.random(n) < 0.3,
            rng.integers(0, 3, size=n).astype(np.int32))


def _sum_rows(stats: ctl.Stats) -> ctl.Stats:
    return type(stats)(*[np.asarray(x).sum(axis=0) for x in stats])


def _assert_int_identical(a: ctl.Stats, b: ctl.Stats, ctx=""):
    for f in ctl.Stats._fields:
        x, y = np.asarray(getattr(a, f)), np.asarray(getattr(b, f))
        if f in ctl._INT_FIELDS:
            assert int(x) == int(y), f"{ctx} {f}: {x} vs {y}"
        else:
            tol = 1e-3 * max(abs(float(x)), 1.0)
            assert abs(float(x) - float(y)) <= tol, \
                f"{ctx} {f}: {x} vs {y}"


# A drawn scenario: trace shape/seed, config axes, partition cuts and
# tenant assignment all come from one strategy so every property sees
# the same distribution.  Lengths are drawn coarse (multiples of 100)
# to bound the number of distinct padded shapes XLA has to compile.
_scenario = st.fixed_dictionaries({
    "n": st.integers(6, 14).map(lambda k: k * 100),
    "span": st.sampled_from([512, 2048]),
    "seed": st.integers(0, 2 ** 16),
    "pred": st.sampled_from(_PREDS),
    "comp": st.booleans(),
    "cuts": st.lists(st.integers(1, 99), min_size=0, max_size=4,
                     unique=True),
    "n_tenants": st.integers(2, 4),
})


def _bounds(n: int, cuts) -> list:
    """Turn percentage cut points into epoch [start, end) bounds."""
    edges = sorted({0, n} | {max(1, min(n - 1, c * n // 100))
                             for c in cuts})
    return list(zip(edges[:-1], edges[1:]))


def _monolithic(cfg, trace, warmup) -> ctl.Stats:
    addrs, writes, levels = trace
    return engine.simulate_parallel(cfg, addrs, writes, levels, warmup)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(_scenario)
def test_epoch_partition_bit_identity(sc):
    """Any partition, streamed with pos0 offsets, == one dispatch."""
    cfg = _cfg(sc["pred"], sc["comp"])
    addrs, writes, levels = _trace(sc["n"], sc["span"], sc["seed"])
    warmup = sc["n"] // 4
    state = engine.init_state(cfg, 1)
    total = None
    for a, b in _bounds(sc["n"], sc["cuts"]):
        pt = engine.pack(cfg, [(addrs[a:b], writes[a:b], levels[a:b],
                                warmup)], pos0=[a])
        state, delta = engine.advance_packed(cfg, pt, state)
        delta = ctl.Stats(*[np.asarray(x)[0] for x in delta])
        total = delta if total is None else \
            ctl.Stats(*[x + y for x, y in zip(total, delta)])
    mono = _monolithic(cfg, (addrs, writes, levels), warmup)
    _assert_int_identical(total, mono,
                          f"partition {sc['cuts']} pred={sc['pred']}")


@settings(max_examples=10, deadline=None, derandomize=True)
@given(_scenario)
def test_tenant_masks_sum_to_global(sc):
    """Count-masked replays over a mask partition sum bit-identically."""
    cfg = _cfg(sc["pred"], sc["comp"])
    addrs, writes, levels = _trace(sc["n"], sc["span"], sc["seed"])
    warmup = sc["n"] // 4
    k = sc["n_tenants"]
    rng = np.random.default_rng(sc["seed"] + 1)
    tenant = rng.integers(0, k, size=sc["n"])
    masks = [tenant == t for t in range(k)]
    pt = engine.pack(cfg, [(addrs, writes, levels, warmup)] * k,
                     count=masks)
    per_tenant = engine._run_packed(cfg, pt, engine.resolve_backend(None))
    mono = _monolithic(cfg, (addrs, writes, levels), warmup)
    summed = _sum_rows(per_tenant)
    for f in ctl._INT_FIELDS:
        assert int(np.asarray(getattr(summed, f))) == \
            int(np.asarray(getattr(mono, f))), \
            f"{f}: masked sum != global (k={k}, pred={sc['pred']})"


@settings(max_examples=8, deadline=None, derandomize=True)
@given(_scenario)
def test_masked_epoch_streaming_sums_to_global(sc):
    """The fleet/churn path: per-tenant masks x epoch partition at once.

    K state rows advance through every epoch slice with count masks;
    the K x E deltas summed over both axes must equal the monolithic
    unmasked run on every integer counter.
    """
    cfg = _cfg(sc["pred"], sc["comp"])
    addrs, writes, levels = _trace(sc["n"], sc["span"], sc["seed"])
    warmup = sc["n"] // 4
    k = sc["n_tenants"]
    rng = np.random.default_rng(sc["seed"] + 2)
    tenant = rng.integers(0, k, size=sc["n"])
    state = engine.init_state(cfg, k)
    total = None
    for a, b in _bounds(sc["n"], sc["cuts"]):
        masks = [(tenant == t)[a:b] for t in range(k)]
        pt = engine.pack(cfg, [(addrs[a:b], writes[a:b], levels[a:b],
                                warmup)] * k, pos0=[a] * k, count=masks)
        state, delta = engine.advance_packed(cfg, pt, state)
        delta = _sum_rows(delta)
        total = delta if total is None else \
            ctl.Stats(*[x + y for x, y in zip(total, delta)])
    mono = _monolithic(cfg, (addrs, writes, levels), warmup)
    for f in ctl._INT_FIELDS:
        assert int(np.asarray(getattr(total, f))) == \
            int(np.asarray(getattr(mono, f))), \
            f"{f}: masked stream != global (k={k}, cuts={sc['cuts']})"


# ---------------------------------------------- overload QoS invariants
# (PR: overload-aware admission — docs/qos.md.)  hypothesis draws the
# quota vectors, tenant sets, admission knobs and demand histories the
# fixed scenarios in tests/test_overload.py never anticipate.

from repro.runtime.admission import (AdmissionConfig,  # noqa: E402
                                     AdmissionController)
from repro.workloads.serving import (TenantSLO,  # noqa: E402
                                     apportion_largest_remainder)


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.lists(st.floats(0.0, 1e6, allow_nan=False,
                          allow_infinity=False),
                min_size=1, max_size=8),
       st.integers(0, 10_000))
def test_largest_remainder_conserves_total(quotas, total):
    """Apportionment conserves the round total exactly for ANY quota
    vector, and never strays more than one unit from the ideal share."""
    out = apportion_largest_remainder(quotas, total)
    assert sum(out) == total
    assert all(v >= 0 for v in out)
    s = sum(quotas)
    if s > 0:
        for q, v in zip(quotas, out):
            ideal = q / s * total
            assert ideal - 1 - 1e-6 < v < ideal + 1 + 1e-6


def _draw_admission(data):
    k = data.draw(st.integers(2, 4))
    tenants = [TenantSLO(f"t{i}", 5.0, weight=1.0,
                         priority=data.draw(st.integers(0, 3)))
               for i in range(k)]
    cfg = AdmissionConfig(age_boost=data.draw(st.integers(1, 4)),
                          defer_cap=data.draw(st.integers(1, 16)))
    cap = data.draw(st.integers(1, 12))
    budgets = dict(zip([t.name for t in tenants],
                       apportion_largest_remainder([1.0] * k, cap)))
    history = [
        {t.name: data.draw(st.integers(0, 10)) for t in tenants}
        for _ in range(data.draw(st.integers(5, 25)))]
    return tenants, cfg, cap, budgets, history


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.data())
def test_admission_starvation_freedom(data):
    """Aging bounds every tenant's wait: once a batch reaches age_boost
    it outranks all fresh work oldest-first, and the total backlog is
    capped at K x defer_cap, so no oldest batch can wait longer than
    age_boost + the rounds one capacity-bounded drain takes — for ANY
    demand history and priority assignment."""
    tenants, cfg, cap, budgets, history = _draw_admission(data)
    ctrl = AdmissionController(tenants, cfg)
    bound = cfg.age_boost \
        + -(-len(tenants) * cfg.defer_cap // cap) + 1   # ceil drain
    for demand in history:
        p = ctrl.plan(demand, budgets)
        # round conservation, every tenant, every round
        for n in demand:
            assert demand[n] == p.admitted[n] + p.deferred[n] + p.shed[n]
        assert p.total_served <= cap
        for t in tenants:
            assert ctrl.oldest_age(t.name) <= bound, \
                (t.name, ctrl.oldest_age(t.name), bound)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.data())
def test_admission_plan_is_pure(data):
    """Admission decisions are a pure function of (tenant set, config,
    demand history): two fresh controllers replaying the same drawn
    history emit byte-identical event traces and counters.  (The
    cross-process half of this claim is pinned by
    tests/test_overload.py::test_plan_is_pure_across_processes.)"""
    tenants, cfg, cap, budgets, history = _draw_admission(data)

    def replay():
        ctrl = AdmissionController(tenants, cfg)
        for demand in history:
            ctrl.plan(demand, budgets)
        return (";".join(e.compact() for e in ctrl.events),
                dict(ctrl.counters),
                {n: ctrl.queues[n] for n in ctrl.names})

    assert replay() == replay()
