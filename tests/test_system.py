"""End-to-end system tests: the full stack working together.

These exercise the public API the examples use — train loop with
checkpoint/restart + fault supervisor, the serving engine with the
Morpheus tier, and the mode-split policy — on reduced configs.
"""
import jax
import numpy as np

from repro import configs
from repro.core import cache_sim as cs
from repro.core.policy import best_split
from repro.models import build_model
from repro.serving import Engine, Request
from repro.train.loop import train


def test_train_loop_decreases_loss_and_checkpoints(tmp_path):
    cfg = configs.get("h2o-danube-1.8b").reduced()
    state, losses, rep = train(cfg, steps=24, batch=4, seq=64,
                               ckpt_dir=str(tmp_path), ckpt_every=8)
    assert rep.steps_run == 24
    assert losses[-1] < losses[0]
    # restart resumes from the persisted step and continues
    state2, losses2, rep2 = train(cfg, steps=30, batch=4, seq=64,
                                  ckpt_dir=str(tmp_path), ckpt_every=100)
    assert rep2.resumed_from == 24
    assert rep2.steps_run == 6


def test_training_step_is_deterministic():
    cfg = configs.get("qwen3-4b").reduced()
    out = []
    for _ in range(2):
        _, losses, _ = train(cfg, steps=4, batch=2, seq=32, seed=7)
        out.append(losses)
    np.testing.assert_allclose(out[0], out[1], rtol=1e-6)


def test_serving_engine_morpheus_transparent_second_arch():
    """The extended tier must never change generated tokens (gemma2:
    local+global alternating layers + softcap)."""
    cfg = configs.get("gemma2-9b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [(3 * j + 5) % 97 + 1 for j in range(24)]
    outs = []
    for morpheus in (True, False):
        reqs = [Request(rid=0, prompt=prompt, max_new_tokens=5)]
        Engine(model, params, max_len=48, morpheus=morpheus).run(reqs)
        outs.append(reqs[0].out_tokens)
    assert outs[0] == outs[1]
    assert len(outs[0]) == 5


def test_policy_mode_split_sane():
    """The Table-3 analogue: memory-bound apps give cores to the cache
    tier; the chosen split must beat the all-compute baseline."""
    split = best_split("kmeans", "Morpheus-ALL", length=16_000)
    assert 0 < split.n_cache <= int(cs.TOTAL_CORES * cs.MAX_CACHE_FRAC)
    assert split.n_compute + split.n_cache <= cs.TOTAL_CORES
    bl = cs.run("kmeans", "BL", n_compute=cs.TOTAL_CORES, length=16_000)
    assert split.exec_time_s < bl.exec_time_s
