"""Shared overload-scenario fixtures (tests/test_overload.py).

One canonical three-tenant set and one fixed round capacity, replayed
against the canonical ``repro.workloads.overload.SCENARIOS`` shapes —
the same definitions ``benchmarks/fig_overload.py`` sweeps, so a shape
or controller change fails the pinned goldens here before it skews a
figure.  The goldens are CRC32s over the controller's compact event
trace: the admission planner is a pure function of (tenants, config,
demand history), so the trace is byte-stable across processes and
platforms — ``GOLDEN_CRC`` pins exactly that.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Tuple

from repro.runtime.admission import AdmissionConfig, AdmissionController
from repro.workloads.overload import (LoadScenario, SCENARIOS,
                                      demand_schedule)
from repro.workloads.serving import (TenantSLO,
                                     apportion_largest_remainder)

# Canonical tenant set: tight-SLO heavyweight, middleweight, best-effort.
TENANTS = [
    TenantSLO("hi", 4.0, weight=2.0, priority=2, app="cfd"),
    TenantSLO("mid", 8.0, weight=1.0, priority=1, app="kmeans"),
    TenantSLO("lo", 16.0, weight=1.0, priority=0, app="histo"),
]
BASE_TOTAL = 24     # 1x offered round size
CAPACITY = 24       # fixed round capacity the pinned traces assume


def fixed_budgets() -> Dict[str, int]:
    """Weight-apportioned CAPACITY — the budgeter's cold-start split,
    held fixed so the pinned traces exercise only the controller."""
    shares = apportion_largest_remainder([t.weight for t in TENANTS],
                                         CAPACITY)
    return dict(zip([t.name for t in TENANTS], shares))


def run_controller(scn: LoadScenario,
                   cfg: AdmissionConfig = AdmissionConfig()
                   ) -> Tuple[AdmissionController, List]:
    """Replay one scenario's demand through a fresh controller under the
    fixed budgets; returns (controller, per-round plans)."""
    ctrl = AdmissionController(TENANTS, cfg)
    budgets = fixed_budgets()
    plans = [ctrl.plan(demand, budgets)
             for demand in demand_schedule(scn, TENANTS, BASE_TOTAL)]
    return ctrl, plans


def event_trace(ctrl: AdmissionController) -> str:
    return ";".join(e.compact() for e in ctrl.events)


def event_crc(ctrl: AdmissionController) -> int:
    return zlib.crc32(event_trace(ctrl).encode()) & 0xFFFFFFFF


# CRC32 of the compact event trace per canonical scenario (computed by
# replaying run_controller once; test_overload.py re-derives and
# compares).  Recompute deliberately — a mismatch means the admission
# semantics changed, which must be an intentional, reviewed change:
#   python -c "import sys; sys.path[:0]=['src','tests']; \
#       import scenarios as s; print({k: s.event_crc(\
#       s.run_controller(v)[0]) for k, v in s.SCENARIOS.items()})"
GOLDEN_CRC = {
    "step4": 2564149082,
    "spike6": 3053713432,
    "sustained2": 2998492347,
    "sustained8": 3902337022,
}
