"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def rng(i=0):
    return np.random.default_rng(i)


# ------------------------------------------------------------ tag lookup

@pytest.mark.parametrize("sets,ways", [(256, 32), (512, 16), (1024, 8),
                                       (256, 64)])
def test_tag_lookup_matches_ref(sets, ways):
    r = rng(1)
    tags = jnp.asarray(r.integers(0, 64, (sets, ways), dtype=np.uint32))
    valid = jnp.asarray(r.random((sets, ways)) < 0.7)
    lru = jnp.asarray(r.integers(0, 4096, (sets, ways), dtype=np.uint32))
    req = jnp.asarray(r.integers(0, 64, (sets,), dtype=np.uint32))

    hit_k, way_k, lru_k = ops.tag_lookup(tags, valid, lru, req)
    hit_r, way_r, lru_r = ref.tag_lookup(tags, valid, lru, req)

    np.testing.assert_array_equal(np.asarray(hit_k, bool), np.asarray(hit_r))
    # way only defined on hit
    h = np.asarray(hit_r)
    np.testing.assert_array_equal(np.asarray(way_k)[h], np.asarray(way_r)[h])
    np.testing.assert_array_equal(np.asarray(lru_k), np.asarray(lru_r))


def test_tag_lookup_hit_way_correct():
    tags = jnp.asarray([[5, 9, 7, 7]], dtype=jnp.uint32)
    valid = jnp.asarray([[True, True, False, True]])
    lru = jnp.zeros((1, 4), jnp.uint32)
    hit, way, new_lru = ops.tag_lookup(tags, valid, lru,
                                       jnp.asarray([7], jnp.uint32))
    assert bool(hit[0]) and int(way[0]) == 3  # way 2 invalid -> way 3
    assert int(new_lru[0, 3]) == 0xFFF


# ------------------------------------------------------------------ BDI

@pytest.mark.parametrize("n", [256, 1024])
@pytest.mark.parametrize("kind", ["high", "low", "uncomp", "mixed"])
def test_bdi_roundtrip_and_levels(n, kind):
    r = rng(2)
    base = r.integers(0, 2 ** 32, n, dtype=np.uint64)
    if kind == "high":
        deltas = r.integers(-128, 128, (n, 32))
    elif kind == "low":
        deltas = r.integers(-32768, 32768, (n, 32))
    elif kind == "uncomp":
        deltas = r.integers(-2 ** 31, 2 ** 31, (n, 32))
    else:
        deltas = r.integers(-128, 128, (n, 32)) * \
            r.integers(1, 2 ** 18, (n, 1))
    blocks = ((base[:, None] + deltas) % 2 ** 32).astype(np.uint32)
    blocks[:, 0] = base.astype(np.uint32)  # delta-from-first-segment
    blocks = jnp.asarray(blocks)

    lvl_k, base_k, pay_k = ops.bdi_compress(blocks)
    lvl_r, base_r, pay_r = ref.bdi_compress(blocks)
    np.testing.assert_array_equal(np.asarray(lvl_k), np.asarray(lvl_r))
    np.testing.assert_array_equal(np.asarray(base_k), np.asarray(base_r))
    np.testing.assert_array_equal(np.asarray(pay_k), np.asarray(pay_r))

    out = ops.bdi_decompress(lvl_k, base_k, pay_k)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(blocks))

    if kind == "high":
        assert (np.asarray(lvl_k) == 0).all()
    if kind == "uncomp":
        assert (np.asarray(lvl_k) == 2).mean() > 0.95


# --------------------------------------------------------- gather blocks

@pytest.mark.parametrize("sets,ways,words", [(64, 32, 32), (128, 8, 32),
                                             (64, 16, 16)])
def test_gather_blocks_matches_ref(sets, ways, words):
    r = rng(3)
    data = jnp.asarray(r.integers(0, 2 ** 32, (sets, ways, words),
                                  dtype=np.uint32))
    way = jnp.asarray(r.integers(0, ways, (sets,), dtype=np.int32))
    out_k = ops.gather_blocks(data, way)
    out_r = ref.gather_blocks(data, way)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ----------------------------------------------------------- bloom query

@pytest.mark.parametrize("q,words", [(512, 8), (1024, 16)])
def test_bloom_query_matches_ref(q, words):
    r = rng(4)
    filters = jnp.asarray(r.integers(0, 2 ** 32, (q, words), dtype=np.uint32))
    tags = jnp.asarray(r.integers(0, 2 ** 24, (q,), dtype=np.uint32))
    pred_k, masks_k = ops.bloom_query(filters, tags)
    pred_r = ref.bloom_query(filters, tags)
    np.testing.assert_array_equal(np.asarray(pred_k, bool),
                                  np.asarray(pred_r))
    # inserting via the masks must make every tag predicted-present
    pred2, _ = ops.bloom_query(filters | masks_k, tags)
    assert np.asarray(pred2, bool).all()


def test_bloom_insert_masks_match_ref_insert():
    r = rng(5)
    filters = jnp.zeros((512, 8), jnp.uint32)
    tags = jnp.asarray(r.integers(0, 2 ** 24, (512,), dtype=np.uint32))
    _, masks = ops.bloom_query(filters, tags)
    np.testing.assert_array_equal(np.asarray(filters | masks),
                                  np.asarray(ref.bloom_insert(filters, tags)))


# ----------------------------------------------------------- decode attn

@pytest.mark.parametrize("b,h,kvh,hd,t", [
    (2, 8, 8, 64, 1024), (2, 8, 2, 64, 1024), (1, 16, 4, 128, 2048),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(b, h, kvh, hd, t, dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, hd), dtype)
    k = jax.random.normal(k2, (b, t, kvh, hd), dtype)
    v = jax.random.normal(k3, (b, t, kvh, hd), dtype)
    valid = jnp.asarray(rng(6).random((b, t)) < 0.9)

    out_k = ops.decode_attention(q, k, v, valid)
    out_r = ref.decode_attention(q, k, v, valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


def test_decode_attention_respects_mask():
    """Fully masking all but one position returns (approx) that value."""
    b, h, kvh, hd, t = 1, 4, 4, 64, 512
    q = jnp.ones((b, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, kvh, hd))
    valid = jnp.zeros((b, t), bool).at[:, 137].set(True)
    out = ops.decode_attention(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(v[:, 137]).reshape(b, h, hd),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ flash attn

@pytest.mark.parametrize("b,s,t,h,kv,hd,hdv,causal,window,cap", [
    (2, 256, 256, 4, 2, 64, 64, True, 0, 0.0),      # GQA causal
    (1, 200, 200, 4, 4, 32, 32, True, 0, 50.0),     # softcap + ragged seq
    (2, 128, 384, 2, 1, 64, 32, False, 0, 0.0),     # cross-attn, MLA v-dim
    (1, 256, 256, 8, 2, 64, 64, True, 96, 0.0),     # sliding window
])
def test_flash_attention_matches_ref(b, s, t, h, kv, hd, hdv, causal,
                                     window, cap):
    r = rng(7)
    q = jnp.asarray(r.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, t, kv, hdv)), jnp.float32)
    o_k = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    o_r = ref.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cap)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    r = rng(8)
    q = jnp.asarray(r.normal(size=(1, 128, 4, 64)), jnp.bfloat16)
    k = jnp.asarray(r.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(r.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    o_k = ops.flash_attention(q, k, v)
    o_r = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=3e-2, atol=3e-2)
