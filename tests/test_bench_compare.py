"""bench_compare warn-and-skip contract for one-sided timing labels.

A bench revision may add or retire timings (tools/bench_autotune.py is
the first bench to land after baselines were committed); the compare
gate must warn and keep diffing the shared labels — never error — while
still flagging warm regressions among what both files have.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))

import bench_compare  # noqa: E402
import bench_schema as bs  # noqa: E402


def _pair(tmp_path, base_t, new_t):
    a = bs.write_bench("unit", "quick", base_t, path=tmp_path / "a.json")
    b = bs.write_bench("unit", "quick", new_t, path=tmp_path / "b.json")
    return a, b


def test_one_sided_labels_warn_and_pass(tmp_path, capsys):
    """New-only and base-only labels are skipped with a warning, and the
    shared label (no regression) keeps the exit code at 0."""
    a, b = _pair(tmp_path, {"step warm": 1.0, "retired warm": 9.0},
                 {"step warm": 1.01, "added warm": 0.5})
    assert bench_compare.compare(a, b, 0.10) == 0
    cap = capsys.readouterr()
    assert "warning:" in cap.err and "skipped, not gated" in cap.err
    assert "added warm" in cap.err and "retired warm" in cap.err
    assert "retired warm" not in cap.out, "skipped labels are not diffed"


def test_one_sided_labels_do_not_mask_shared_regression(tmp_path, capsys):
    """Skipping one-sided labels must not swallow a real warm regression
    on a label both files have."""
    a, b = _pair(tmp_path, {"step warm": 1.0},
                 {"step warm": 1.5, "added warm": 0.1})
    assert bench_compare.compare(a, b, 0.10) == 1
    cap = capsys.readouterr()
    assert "warning:" in cap.err
    assert "REGRESSED" in cap.out


def test_fully_disjoint_timings_warn_and_pass(tmp_path, capsys):
    """Zero shared labels: nothing to gate on, warn-and-pass (the old
    behaviour a hard error here would break: comparing across bench
    revisions that renamed every label)."""
    a, b = _pair(tmp_path, {"old warm": 1.0}, {"new warm": 2.0})
    assert bench_compare.compare(a, b, 0.10) == 0
    cap = capsys.readouterr()
    assert "warning: 2 timing label(s)" in cap.err
    assert "no warm regression" in cap.out


def test_cold_only_one_sided_labels_still_warn(tmp_path, capsys):
    a, b = _pair(tmp_path, {"step warm": 1.0, "jit cold": 3.0},
                 {"step warm": 1.0})
    assert bench_compare.compare(a, b, 0.10) == 0
    assert "jit cold" in capsys.readouterr().err


def test_mismatched_bench_still_errors(tmp_path):
    """Warn-and-skip is for labels; comparing two different benches is
    still a usage error (exit 2)."""
    a = bs.write_bench("unit", "quick", {"x warm": 1.0},
                       path=tmp_path / "a.json")
    b = bs.write_bench("other", "quick", {"x warm": 1.0},
                       path=tmp_path / "b.json")
    assert bench_compare.compare(a, b, 0.10) == 2
