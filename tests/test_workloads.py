"""Workload-subsystem tests: sources/registry, corpus round-trip,
arrival-process statistics, multi-tenant composition and attribution.

Headline properties (ISSUE 4 acceptance):

  * corpus save -> load -> replay is bit-identical, across processes
    (trace generation is a pure function of its parameters — pinned by a
    golden checksum, which would have caught the salted-``hash(app)``
    seeding this PR fixed);
  * multi-tenant composition is deterministic and per-tenant Stats sum
    to the global Stats bit-identically on integer counters;
  * a single-tenant deterministic-arrival ``Workload`` replayed through
    ``EpochStream`` is bit-identical to the raw-array path, on both
    engine backends.
"""
import zlib

import numpy as np
import pytest

from repro.core import address_separation as asep
from repro.core import controller as ctl
from repro.core import engine
from repro.runtime import EpochStream
from repro.workloads import arrivals as arrlib
from repro.workloads import corpus, sources, synthetic, tenancy
from repro.workloads.serving import round_sizes, tenant_prompts


def _cfg(conv_sets=8, chips=2, sets_per_chip=4, **kw):
    amap = asep.make_map(conv_sets=conv_sets, num_cache_chips=chips,
                         sets_per_chip=sets_per_chip)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4, **kw)


def _int_identical(a: ctl.Stats, b: ctl.Stats, ctx=""):
    for f in ctl._INT_FIELDS:
        x = int(np.asarray(getattr(a, f)))
        y = int(np.asarray(getattr(b, f)))
        assert x == y, f"{ctx} {f}: {x} vs {y}"


# ------------------------------------------------------------- sources

def test_source_registry_specs():
    s = sources.make_source("synthetic:cfd")
    assert (s.name, s.app) == ("synthetic:cfd", "cfd")
    assert sources.make_source("cfd").name == "synthetic:cfd"   # sugar
    p = sources.make_source("phased:kmeans+lib")
    assert p.apps == ("kmeans", "lib")
    assert p.app == "kmeans"            # primary = first memory-bound
    assert sources.make_source("kmeans+lib").apps == ("kmeans", "lib")
    with pytest.raises(ValueError):
        sources.make_source("synthetic:no-such-app")
    with pytest.raises(ValueError):
        sources.make_source("not/a/registered/thing")


def test_source_registry_is_pluggable():
    class Fixed:
        name = "fixed:unit"
        app = "cfd"

        def generate(self, *, n_cores, length, seed=0, ws_scale=1.0):
            return (np.zeros(length, np.uint32), np.zeros(length, bool),
                    np.zeros(length, np.int32))

    sources.register_source("fixedtest", lambda rest: Fixed())
    try:
        s = sources.make_source("fixedtest:whatever")
        assert isinstance(s, Fixed)
        assert isinstance(s, sources.TraceSource)   # protocol conformance
    finally:
        sources.SOURCE_KINDS.pop("fixedtest")


def test_synthetic_generation_is_process_stable():
    """Traces are a pure function of their parameters: the golden crc
    pins content across processes and sessions (hash(app) seeding was
    salted per process — this is the regression test for that fix)."""
    a, w, l = synthetic.generate("cfd", n_cores=8, length=4000, seed=3,
                                 ws_scale=0.125)
    assert (zlib.crc32(a.tobytes()), zlib.crc32(w.tobytes()),
            zlib.crc32(l.tobytes())) == \
        (1118088029, 821650521, 862733448)


# -------------------------------------------------------------- corpus

def test_corpus_round_trip_bit_identity(tmp_path):
    a, w, l = synthetic.generate("kmeans", n_cores=4, length=5000, seed=1)
    p = corpus.save_trace(tmp_path / "t.npz", a, w, l, name="t",
                          like="kmeans", n_cores=4, seed=1)
    a2, w2, l2, meta = corpus.load_trace(p)
    np.testing.assert_array_equal(a, a2)
    np.testing.assert_array_equal(w, w2)
    np.testing.assert_array_equal(l, l2)
    assert meta["like"] == "kmeans" and meta["schema"] == corpus.SCHEMA_VERSION

    src = sources.make_source(f"corpus:{p}")
    assert src.app == "kmeans"
    r = src.generate(n_cores=99, length=5000)     # n_cores ignored: replay
    for x, y in zip(r, (a, w, l)):
        np.testing.assert_array_equal(x, y)
    # tiling: replay longer than the recording wraps around
    r3 = src.generate(n_cores=1, length=7500)
    np.testing.assert_array_equal(r3[0][5000:], a[:2500])


def test_corpus_validation_rejects_malformed(tmp_path):
    a, w, l = synthetic.generate("cfd", n_cores=2, length=100)
    good = corpus.save_trace(tmp_path / "good.npz", a, w, l)
    assert corpus.validate_trace(good) == []
    # wrong dtype
    bad = tmp_path / "bad.npz"
    np.savez(bad, addrs=a.astype(np.int64), writes=w, levels=l,
             meta=np.str_('{"schema": 1}'))
    assert any("dtype" in e for e in corpus.validate_trace(bad))
    # bad level codes
    bad2 = tmp_path / "bad2.npz"
    np.savez(bad2, addrs=a, writes=w, levels=np.full(100, 7, np.int32),
             meta=np.str_('{"schema": 1}'))
    assert any("levels" in e for e in corpus.validate_trace(bad2))
    # not a corpus at all
    bad3 = tmp_path / "bad3.npz"
    np.savez(bad3, foo=a)
    assert corpus.validate_trace(bad3)
    with pytest.raises(ValueError):
        corpus.load_trace(bad3)


# ------------------------------------------------------------ arrivals

def test_arrival_statistics_within_tolerance():
    """Empirical rate and burstiness match each process's contract under
    a fixed seed: det CV=0, Poisson CV~1, MMPP CV>1.3, and every stream
    is monotone nondecreasing at the requested mean rate (+-10%)."""
    n = 20_000
    det = arrlib.Deterministic(2e6).timestamps(n, seed=0)
    poi = arrlib.Poisson(2e6).timestamps(n, seed=0)
    # short sojourns so the trace spans many on/off cycles — the
    # empirical rate of an MMPP converges per *cycle*, not per arrival
    mmpp_proc = arrlib.MMPP(4e5, 6e6, 2e-4, 6e-5)
    mmpp = mmpp_proc.timestamps(n, seed=0)
    for ts, rate, tol in ((det, 2e6, 0.01), (poi, 2e6, 0.05),
                          (mmpp, mmpp_proc.mean_rate(), 0.20)):
        assert np.all(np.diff(ts) >= 0)
        assert ts[0] == 0.0
        assert arrlib.empirical_rate(ts) == pytest.approx(rate, rel=tol)
    assert arrlib.burstiness(det) < 1e-9
    assert arrlib.burstiness(poi) == pytest.approx(1.0, abs=0.05)
    assert arrlib.burstiness(mmpp) > 1.3
    # on-off sugar: silence periods make it burstier than plain Poisson
    onoff = arrlib.make_arrival("onoff:6e6,1.5e-3,3e-3").timestamps(n, 0)
    assert arrlib.burstiness(onoff) > 1.3


def test_arrival_determinism_and_seed_sensitivity():
    p = arrlib.Poisson(1e6)
    np.testing.assert_array_equal(p.timestamps(500, seed=4),
                                  p.timestamps(500, seed=4))
    assert not np.array_equal(p.timestamps(500, seed=4),
                              p.timestamps(500, seed=5))
    m = arrlib.MMPP(0.0, 5e6, 1e-3, 1e-3)       # on-off: rate_a = 0
    ts = m.timestamps(2000, seed=2)
    assert len(ts) == 2000 and np.all(np.diff(ts) >= 0)


def test_arrival_spec_parsing():
    assert isinstance(arrlib.make_arrival("det:1e6"), arrlib.Deterministic)
    assert isinstance(arrlib.make_arrival("poisson:2e5"), arrlib.Poisson)
    m = arrlib.make_arrival("mmpp:1e5,2e6,1e-3,5e-4")
    assert (m.rate_a, m.rate_b) == (1e5, 2e6)
    o = arrlib.make_arrival("onoff:2e6,1e-3,3e-3")
    assert o.rate_a == 0.0 and o.mean_sojourn_b == 1e-3
    for bad in ("det", "det:0", "mmpp:1,2", "warp:1e6"):
        with pytest.raises(ValueError):
            arrlib.make_arrival(bad)


def test_epochs_by_time_variable_sizes():
    ts = np.concatenate([np.linspace(0, 1e-3, 100, endpoint=False),
                         np.linspace(5e-3, 5.1e-3, 900)])
    bounds = arrlib.epochs_by_time(ts, 1e-3, min_requests=10)
    assert bounds[0] == (0, 100)
    assert bounds[-1][1] == 1000
    sizes = [hi - lo for lo, hi in bounds]
    assert max(sizes) >= 900          # the burst lands in one fat epoch
    # bounds tile the stream exactly
    assert bounds[0][0] == 0
    for (l0, h0), (l1, h1) in zip(bounds, bounds[1:]):
        assert h0 == l1


# ------------------------------------------------------------- tenancy

def test_compose_deterministic_and_seed_sensitive():
    kw = dict(length=6000, n_cores=4, arrival="poisson:2e6")
    w1 = tenancy.make_workload("cfd,kmeans", seed=0, **kw)
    w2 = tenancy.make_workload("cfd,kmeans", seed=0, **kw)
    w3 = tenancy.make_workload("cfd,kmeans", seed=1, **kw)
    np.testing.assert_array_equal(w1.addrs, w2.addrs)
    np.testing.assert_array_equal(w1.tenant_id, w2.tenant_id)
    np.testing.assert_array_equal(w1.t_s, w2.t_s)
    assert not np.array_equal(w1.addrs, w3.addrs)


def test_compose_tenant_address_spaces_disjoint():
    wl = tenancy.make_workload("cfd,kmeans,lib", length=6000, n_cores=4,
                               arrival="det:1e6")
    region = wl.addrs // np.uint32(tenancy.TENANT_STRIDE_BLOCKS)
    np.testing.assert_array_equal(region, wl.tenant_id.astype(np.uint32))
    assert np.all(np.diff(wl.t_s) >= 0)          # merged by arrival time
    # weights steer the volume split
    w2 = tenancy.make_workload("cfd,kmeans*3", length=8000, n_cores=4,
                               arrival="det:1e6")
    counts = w2.tenant_counts()
    assert counts[1] == pytest.approx(3 * counts[0], rel=0.01)


def test_make_workload_per_tenant_arrival_overrides():
    """Commas inside mmpp/onoff arrival args must not be parsed as new
    tenants (the docstring's own example)."""
    wl = tenancy.make_workload("cfd@det:2e6,kmeans@onoff:8e6,1e-3,3e-3",
                               length=4000, n_cores=4)
    assert wl.names == ["t0:cfd", "t1:kmeans"]
    assert isinstance(wl.tenants[0].arrival, arrlib.Deterministic)
    mm = wl.tenants[1].arrival
    assert isinstance(mm, arrlib.MMPP) and mm.rate_a == 0.0


def test_compose_counts_sum_exactly_to_length():
    """Weight apportionment never over/undershoots the requested length,
    even with extreme weights (each tenant keeps a 1-request floor)."""
    for spec, n in (("cfd,kmeans*0.0000001", 100),
                    ("cfd*3,kmeans*2,lib", 101),
                    ("cfd,kmeans,lib", 4)):
        wl = tenancy.make_workload(spec, length=n, n_cores=2,
                                   arrival="det:1e6")
        assert len(wl) == n, (spec, n, len(wl))
        assert all(c >= 1 for c in wl.tenant_counts())


def test_per_tenant_stats_sum_to_global():
    """Attribution invariant: masked per-tenant replays partition the
    requests, so per-tenant Stats sum to the unmasked global run
    bit-identically on every integer counter."""
    import jax
    cfg = _cfg(compression=True)
    wl = tenancy.make_workload("cfd,kmeans", length=4000, n_cores=4,
                               arrival="mmpp:4e5,6e6,2e-3,6e-4")
    per = tenancy.attribute_stats(cfg, wl, warmup=100)
    assert set(per) == {"t0:cfd", "t1:kmeans"}
    glob = engine.simulate_parallel(cfg, wl.addrs, wl.writes, wl.levels, 100)
    summed = jax.tree.map(lambda *xs: sum(np.asarray(x) for x in xs),
                          *per.values())
    _int_identical(glob, summed, "tenant-sum")
    # every tenant observed some of its own traffic
    for s in per.values():
        total = (s.conv_hits + s.conv_misses + s.ext_hits
                 + s.ext_true_miss)
        assert int(np.asarray(total)) > 0


# ---------------------------------------------- EpochStream integration

def _single_tenant_wl(n=3000):
    return tenancy.make_workload("cfd", length=n, n_cores=4,
                                 arrival="det:2e6", seed=0, ws_scale=0.125)


def test_workload_stream_matches_raw_stream_jnp():
    """Acceptance: a single-tenant deterministic-arrival Workload through
    EpochStream is bit-identical to the raw-array path (jnp backend)."""
    cfg = _cfg(compression=True)
    wl = _single_tenant_wl()
    raw = EpochStream(cfg, wl.addrs, wl.writes, wl.levels, epoch_len=400,
                      backend="jnp")
    via_wl = EpochStream(cfg, wl, epoch_len=400, backend="jnp")
    _int_identical(raw.run(), via_wl.run(), "workload-vs-raw")
    assert via_wl.pos == len(wl)


_pallas_ok, _pallas_why = engine.backend_status("pallas")


@pytest.mark.skipif(not _pallas_ok, reason=_pallas_why)
def test_workload_stream_matches_raw_stream_pallas():
    """Same acceptance property on the Pallas backend (interpret mode
    off-TPU), cross-checked against the jnp monolithic run."""
    cfg = _cfg(compression=True)
    wl = _single_tenant_wl(n=1500)
    mono = engine.simulate_parallel(cfg, wl.addrs, wl.writes, wl.levels, 0,
                                    backend="jnp")
    via_wl = EpochStream(cfg, wl, epoch_len=333, backend="pallas")
    _int_identical(mono, via_wl.run(), "workload-pallas")


def test_multi_tenant_stream_global_equals_single_state_run():
    """K-tenant masked-row replay: the summed per-tenant Stats equal a
    plain single-state replay of the same composed stream, and the
    accumulated tenant split matches attribute_stats exactly."""
    cfg = _cfg()
    wl = tenancy.make_workload("cfd,kmeans", length=3000, n_cores=4,
                               arrival="poisson:2e6")
    multi = EpochStream(cfg, wl, epoch_len=500)
    multi.run()
    plain = EpochStream(cfg, wl.addrs, wl.writes, wl.levels, epoch_len=500)
    _int_identical(plain.run(), multi.stats, "multi-vs-plain")
    per_ref = tenancy.attribute_stats(cfg, wl)
    per_got = multi.tenant_stats()
    for name in per_ref:
        _int_identical(per_ref[name], per_got[name], name)


def test_workload_stream_time_windowed_epochs():
    """Bursty arrivals + window epoching: epochs vary in size but cover
    the stream exactly and reproduce the monolithic integer Stats."""
    cfg = _cfg()
    wl = tenancy.make_workload("cfd", length=4000, n_cores=4,
                               arrival="mmpp:4e5,6e6,2e-3,6e-4",
                               ws_scale=0.125)
    st = EpochStream(cfg, wl, target_epoch=500)
    st.run()
    mono = engine.simulate_parallel(cfg, wl.addrs, wl.writes, wl.levels, 0)
    _int_identical(mono, st.stats, "windowed")
    sizes = [hi - lo for lo, hi in wl.epoch_bounds(target_epoch=500)]
    assert sum(sizes) == len(wl)
    assert len(set(sizes)) > 1, "bursty windows should vary in size"


def test_epoch_stream_ring_bit_identical():
    """The device-resident prepacked ring changes scheduling, never
    Stats."""
    cfg = _cfg(compression=True)
    wl = _single_tenant_wl()
    plain = EpochStream(cfg, wl.addrs, wl.writes, wl.levels, epoch_len=317)
    ring = EpochStream(cfg, wl.addrs, wl.writes, wl.levels, epoch_len=317,
                       ring=4)
    _int_identical(plain.run(), ring.run(), "ring")
    assert ring.epoch == plain.epoch


# ------------------------------------------------------ serving helpers

def test_round_sizes_and_tenant_prompts():
    det = round_sizes("det:100", rounds=5, mean_batch=4, seed=0)
    assert det == [4, 4, 4, 4, 4]
    burst = round_sizes("onoff:100,0.5,0.5", rounds=8, mean_batch=4, seed=0)
    assert sum(burst) == 32 and len(burst) == 8
    assert max(burst) > 4, "on-off rounds should be bursty"
    fams = tenant_prompts("a,b", prompt_len=16)
    assert [n for n, _ in fams] == ["a", "b"]
    assert fams[0][1] != fams[1][1], "tenant prompt families must differ"
    assert all(1 <= t <= 97 for _, toks in fams for t in toks)
