"""QoS-layer tests (ISSUE 5): per-tenant reward objectives, tenant
churn, the SLO round budgeter, the serving governor's idle-window EMA
freeze, and the extended docs checks.

Headline properties (acceptance):

  * the per-tenant weighted reward equals the global reward when the
    weights are uniform and K = 1 (same app, instructions, knee, Stats);
  * churn-boundary count masks still sum to the global Stats
    bit-identically on the jnp AND pallas engine backends;
  * the SLO budgeter converges on a synthetic constant-latency stream;
  * zero-lookup idle windows freeze the serving governor's reward EMA
    (the bugfix: only observe/decide used to be skipped).
"""
import importlib.util
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

from repro.core import address_separation as asep
from repro.core import controller as ctl
from repro.core import engine
from repro.runtime import (EpochStream, Governor, GovernorConfig,
                           ServingGovernor, qos_reward, simulate_online)
from repro.serving.paged_kv import PoolStats
from repro.workloads import tenancy
from repro.workloads.serving import SLOBudgeter, slo_batches


def _cfg(conv_sets=8, chips=2, sets_per_chip=4, **kw):
    amap = asep.make_map(conv_sets=conv_sets, num_cache_chips=chips,
                         sets_per_chip=sets_per_chip)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4, **kw)


def _int_identical(a: ctl.Stats, b: ctl.Stats, ctx=""):
    for f in ctl._INT_FIELDS:
        x = int(np.asarray(getattr(a, f)))
        y = int(np.asarray(getattr(b, f)))
        assert x == y, f"{ctx} {f}: {x} vs {y}"


# ----------------------------------------------------- reward objectives

def test_qos_reward_uniform_single_tenant_is_identity():
    g = GovernorConfig(objective="weighted")
    assert qos_reward(g, [42.5], [100]) == 42.5


def test_qos_reward_weighted_and_minf_semantics():
    gw = GovernorConfig(objective="weighted", tenant_weights=(3.0, 1.0))
    assert qos_reward(gw, [10.0, 50.0], [5, 5]) == \
        pytest.approx(0.75 * 10.0 + 0.25 * 50.0)
    gm = GovernorConfig(objective="minf")
    assert qos_reward(gm, [10.0, 50.0], [5, 5]) == 10.0
    # a heavier weight demands proportionally more IPC to stop binding
    gm2 = GovernorConfig(objective="minf", tenant_weights=(1.0, 10.0))
    assert qos_reward(gm2, [10.0, 50.0], [5, 5]) == \
        pytest.approx(50.0)        # tenant 1 now binds: 50 / 1 vs 10 / 0.1


def test_qos_reward_excludes_inactive_tenants():
    g = GovernorConfig(objective="minf")
    # tenant 1 departed (0 requests): must not pin the min to zero
    assert qos_reward(g, [10.0, 0.0], [5, 0]) == 10.0
    gw = GovernorConfig(objective="weighted", tenant_weights=(1.0, 9.0))
    # weights renormalize over the active set
    assert qos_reward(gw, [10.0, 0.0], [5, 0]) == 10.0
    assert qos_reward(g, [0.0, 0.0], [0, 0]) == 0.0


def test_qos_reward_minf_zero_weight_is_excluded_not_div_zero():
    """weight 0 = no fairness claim: the tenant drops out of the min
    instead of dividing by zero."""
    g = GovernorConfig(objective="minf", tenant_weights=(0.0, 1.0))
    with np.errstate(divide="raise"):
        assert qos_reward(g, [1.0, 50.0], [5, 5]) == 50.0


def test_qos_reward_validates_weights():
    g = GovernorConfig(objective="weighted", tenant_weights=(1.0,))
    with pytest.raises(AssertionError):
        qos_reward(g, [1.0, 2.0], [1, 1])
    with pytest.raises(AssertionError):
        GovernorConfig(objective="no-such-objective")


def test_single_tenant_weighted_run_equals_global_run():
    """Acceptance: K=1 + uniform weights => the weighted objective's
    per-epoch rewards equal the global objective's exactly."""
    wl = tenancy.make_workload("cfd", length=6000, n_cores=8,
                               arrival="det:2e6", ws_scale=0.125)
    kw = dict(epoch_len=1000, fixed_split=(32, 36))
    r_glob = simulate_online(wl, "Morpheus-ALL", **kw)
    r_wtd = simulate_online(wl, "Morpheus-ALL",
                            gcfg=GovernorConfig(objective="weighted"), **kw)
    assert [r.reward for r in r_glob.records] == \
        [r.reward for r in r_wtd.records]
    assert all(r.tenant_ipc for r in r_wtd.records)


# ------------------------------------------------------------ churn: data

def test_window_spec_parsing():
    wl = tenancy.make_workload("cfd@0:0.6,kmeans@0.3:", length=4000,
                               n_cores=4, arrival="det:2e6", ws_scale=0.125)
    assert [t.window for t in wl.tenants] == [(0.0, 0.6), (0.3, 1.0)]
    assert wl.has_churn()
    # arrival override AND window on one tenant; mmpp commas still glue
    wl2 = tenancy.make_workload(
        "cfd@poisson:2e6@0:0.5,kmeans@onoff:8e6,1e-3,3e-3@0.3:",
        length=3000, n_cores=4)
    assert [t.window for t in wl2.tenants] == [(0.0, 0.5), (0.3, 1.0)]
    assert [type(t.arrival).__name__ for t in wl2.tenants] == \
        ["Poisson", "MMPP"]
    with pytest.raises(AssertionError):     # empty window
        tenancy.make_workload("cfd@0.7:0.2", length=100, n_cores=2)
    with pytest.raises(AssertionError):     # duplicate window segments
        tenancy.make_workload("cfd@0:0.5@0.2:0.8", length=100, n_cores=2)


def test_windows_shift_time_and_scale_volume():
    wl = tenancy.make_workload("cfd@0:0.5,kmeans", length=8000, n_cores=4,
                               arrival="det:2e6", ws_scale=0.125)
    counts = wl.tenant_counts()
    # half-window tenant sends ~half the full tenant's volume (same rate)
    assert counts[0] == pytest.approx(counts[1] / 2, rel=0.02)
    t = wl.t_s
    cfd_last = t[wl.tenant_id == 0].max()
    assert cfd_last <= 0.55 * wl.span_s       # departed by its window end
    # no churn => all-default windows, masks constant over epochs
    wl_none = tenancy.make_workload("cfd,kmeans", length=4000, n_cores=4)
    assert not wl_none.has_churn()
    assert wl_none.active_signature(0, 500) == \
        wl_none.active_signature(3500, 4000) == 0b11


def test_active_masks_follow_windows():
    wl = tenancy.make_workload("cfd@0:0.6,kmeans@0.3:", length=6000,
                               n_cores=4, arrival="det:2e6", ws_scale=0.125)
    bounds = wl.epoch_bounds(epoch_len=600)
    sigs = [wl.active_signature(lo, hi) for lo, hi in bounds]
    assert sigs[0] == 0b01                    # only cfd at the start
    assert sigs[-1] == 0b10                   # only kmeans at the end
    assert 0b11 in sigs                       # overlap in the middle
    masks = wl.epoch_active_masks(bounds)
    assert all(m.shape == (2,) for m in masks)
    # window activity, not request presence: every request's tenant is
    # active in its epoch
    for (lo, hi), m in zip(bounds, masks):
        assert all(m[np.unique(wl.tenant_id[lo:hi])])


# ------------------------------------------- churn: attribution invariant

def _churn_stream_sum_check(backend):
    cfg = _cfg(compression=True)
    wl = tenancy.make_workload("cfd@0:0.6,kmeans@0.3:", length=4000,
                               n_cores=4, arrival="det:2e6", ws_scale=0.125)
    st = EpochStream(cfg, wl, epoch_len=500, backend=backend)
    st.run()
    assert st.churn_events, "churn schedule produced no boundary"
    glob = engine.simulate_parallel(cfg, wl.addrs, wl.writes, wl.levels, 0,
                                    backend="jnp")
    import jax
    summed = jax.tree.map(lambda *xs: sum(np.asarray(x) for x in xs),
                          *st.tenant_stats().values())
    _int_identical(glob, summed, f"churn-sum-{backend}")


def test_churn_masks_sum_to_global_jnp():
    """Acceptance: per-tenant Stats of a churn workload sum to the
    monolithic global run bit-identically (jnp backend)."""
    _churn_stream_sum_check("jnp")


def test_churn_masks_exact_under_mismatched_tenant_rates():
    """Regression: activity must follow each tenant's *realized* arrival
    interval, not window fractions of the composed span — with
    per-tenant arrival rates the two frames disagree, and the old
    span-fraction mask marked a tenant departed while its requests were
    still arriving (silently counting them toward no tenant at all)."""
    cfg = _cfg()
    wl = tenancy.make_workload("cfd@det:1e6@0:0.6,kmeans@det:2e6",
                               length=4000, n_cores=4, ws_scale=0.125)
    bounds = wl.epoch_bounds(epoch_len=400)
    for lo, hi in bounds:    # inactive => zero requests, every epoch
        act = wl.active_mask(lo, hi)
        counts = wl.tenant_counts(lo, hi)
        assert all(act[k] or counts[k] == 0 for k in range(2)), \
            (lo, hi, act, counts)
    st = EpochStream(cfg, wl, epoch_len=400)
    st.run()
    glob = engine.simulate_parallel(cfg, wl.addrs, wl.writes, wl.levels, 0)
    import jax
    summed = jax.tree.map(lambda *xs: sum(np.asarray(x) for x in xs),
                          *st.tenant_stats().values())
    _int_identical(glob, summed, "rate-mismatch-sum")


_pallas_ok, _pallas_why = engine.backend_status("pallas")


@pytest.mark.skipif(not _pallas_ok, reason=_pallas_why)
def test_churn_masks_sum_to_global_pallas():
    """Same invariant on the Pallas backend (interpret mode off-TPU)."""
    _churn_stream_sum_check("pallas")


# --------------------------------------------------- churn: governor side

def test_governor_context_first_set_is_not_churn():
    gov = Governor(list(range(4)), GovernorConfig(warm_epochs=0))
    gov.set_context(0b11)
    assert gov.churn_resets == 0
    gov.set_context(0b11)
    assert gov.churn_resets == 0
    gov.set_context(0b01)
    assert gov.churn_resets == 1


def test_governor_context_change_resets_and_remembers():
    cands = list(range(6))
    gov = Governor(cands, GovernorConfig(seed=1, warm_epochs=0))
    reward_a = lambda c: 50.0 - 5 * c          # mix A: best at 0
    reward_b = lambda c: 30.0 + 5 * c          # mix B: best at 5

    def drive(fn, ctx, n):
        for _ in range(n):
            gov.set_context(ctx)
            gov.observe(fn(gov.current), hint=0)
            gov.decide()

    drive(reward_a, 0b11, 40)
    assert gov.current <= 1, gov.est
    est_before = dict(gov.est)
    drive(reward_b, 0b01, 1)                   # churn: B arrives
    assert gov.churn_resets == 1
    assert gov.est != est_before               # estimates were cleared
    drive(reward_b, 0b01, 50)
    assert gov.current >= 4, gov.est
    # re-entering mix A jumps straight to its remembered split
    jumps = gov.phase_jumps
    drive(reward_a, 0b11, 2)
    assert gov.churn_resets == 2
    assert gov.phase_jumps == jumps + 1
    assert gov.current <= 1, (gov.current, gov.ctx_table)


def test_governor_context_scopes_phase_table_keys():
    """The same signature bucket under different contexts must not share
    phase-table entries."""
    gov = Governor(list(range(6)), GovernorConfig(seed=0, warm_epochs=0))
    gov.set_context(0b01)
    gov.observe(10.0, signature=0.5)
    key1 = gov._phase_key
    gov.set_context(0b11)
    gov.observe(10.0, signature=0.5)
    assert gov._phase_key != key1


def test_simulate_online_counts_churn_resets():
    wl = tenancy.make_workload("cfd@0:0.5,kmeans", length=12_000,
                               n_cores=8, arrival="det:2e6", ws_scale=0.125)
    r = simulate_online(wl, "Morpheus-ALL", epoch_len=1500,
                        fixed_split=(32, 36))
    assert r.churn_resets == 1
    wl0 = tenancy.make_workload("cfd,kmeans", length=6_000, n_cores=8,
                                arrival="det:2e6", ws_scale=0.125)
    r0 = simulate_online(wl0, "Morpheus-ALL", epoch_len=1500,
                         fixed_split=(32, 36))
    assert r0.churn_resets == 0


# ----------------------------------------------------------- SLO budgeter

def test_slo_budgeter_converges_on_constant_stream():
    """Acceptance: constant ns/lookup => the budget converges to the
    largest SLO-compliant round size and stays there."""
    b = SLOBudgeter(slo_ms=1.0, min_batch=1, max_batch=256,
                    initial_batch=4)
    ns_per_lookup, lookups_per_req = 12_500.0, 8
    budgets = []
    for _ in range(12):
        n = b.next_budget()
        budgets.append(n)
        b.observe(ns_per_lookup, lookups=n * lookups_per_req, requests=n)
    # 1 ms / (12.5 us * 8) = 10 requests per round
    assert budgets[0] == 4
    assert budgets[-1] == 10 and budgets[-2] == 10
    assert b.ns_per_request == pytest.approx(1e5)


def test_slo_budgeter_clips_and_freezes_on_idle():
    b = SLOBudgeter(slo_ms=100.0, min_batch=2, max_batch=16)
    assert b.next_budget() == 2                # no telemetry yet: min
    b.observe(10.0, lookups=10, requests=10)   # absurdly cheap requests
    assert b.next_budget() == 16               # clipped to max
    before = b.ns_per_request
    b.observe(0.0, lookups=0, requests=0)      # idle round: frozen
    assert b.ns_per_request == before
    assert b.rounds_observed == 1
    with pytest.raises(AssertionError):
        SLOBudgeter(slo_ms=0.0)


def test_slo_batches_round_robin_across_tenants():
    b = SLOBudgeter(slo_ms=1.0, min_batch=4, max_batch=4)
    gen = slo_batches("a,b", b, prompt_len=8)
    batch = next(gen)
    assert [name for name, _ in batch] == ["a", "b", "a", "b"]
    batch2 = next(gen)                         # rotation continues
    assert [name for name, _ in batch2] == ["a", "b", "a", "b"]
    assert all(len(toks) == 8 for _, toks in batch)


# ------------------------------------- serving governor: idle EMA freeze

class _FakePool:
    """Minimal stand-in for MorpheusPagePool: scripted stats deltas."""

    class _Cfg:
        num_cache_chips = 2

    def __init__(self):
        self.cfg = self._Cfg()
        self.stats = PoolStats.zero()

    def busy(self, lookups=100, ns_per_lookup=50.0):
        self.stats = self.stats + PoolStats(
            conv_hits=lookups, conv_misses=0, ext_hits=0, ext_false_pos=0,
            ext_pred_miss=0, backing_fetches=0,
            time_ns=lookups * ns_per_lookup, energy_nJ=0.0)

    def telemetry(self):
        return {"ext_occupancy": 0.5, "pred_accuracy": 1.0}

    def reconfigure(self, n):
        self.cfg.num_cache_chips = n
        return 0


def test_serving_governor_idle_freezes_reward_ema():
    """The bugfix: a long zero-lookup idle gap must leave the reward
    EMA, the estimates and the phase detector untouched — previously
    only observe/decide were skipped."""
    pool = _FakePool()
    sg = ServingGovernor(pool, chip_candidates=(0, 2, 4),
                         gcfg=GovernorConfig(epsilon=0.0, epsilon_min=0.0,
                                             warm_epochs=0))
    for _ in range(4):
        pool.busy()
        sg.tick()
    ema = sg.reward_ema
    est = dict(sg.gov.est)
    eps = sg.gov.eps
    shifts = sg.gov.phase_shifts
    assert ema is not None and est
    for _ in range(50):                        # long idle gap
        rec = sg.tick()
        assert rec["idle"] and rec["reward_ema"] == ema
    assert sg.reward_ema == ema
    assert sg.gov.est == est
    assert sg.gov.eps == eps
    # traffic resumes at the same latency: no spurious phase change
    pool.busy()
    rec = sg.tick()
    assert not rec.get("idle")
    assert sg.gov.phase_shifts == shifts


def test_serving_governor_ema_smooths_reward():
    pool = _FakePool()
    sg = ServingGovernor(pool, chip_candidates=(0, 2, 4), ema_alpha=0.5,
                         gcfg=GovernorConfig(epsilon=0.0, epsilon_min=0.0,
                                             warm_epochs=0))
    pool.busy(ns_per_lookup=50.0)
    r1 = sg.tick()
    assert r1["reward_ema"] == pytest.approx(r1["reward"])
    pool.busy(ns_per_lookup=150.0)
    r2 = sg.tick()
    assert r2["reward_ema"] == pytest.approx(
        0.5 * r1["reward"] + 0.5 * r2["reward"])


def test_serving_governor_ema_reseeds_after_switch():
    """A chip reconfiguration changes the reward's chip-cost term: the
    EMA reseeds at the new split instead of bleeding the old split's
    reward into post-switch estimates."""
    pool = _FakePool()
    sg = ServingGovernor(pool, chip_candidates=(0, 2, 4),
                         gcfg=GovernorConfig(epsilon=1.0, epsilon_min=1.0,
                                             warm_epochs=0, seed=0))
    for _ in range(8):
        pool.busy()
        rec = sg.tick()
        if rec["switched"]:
            assert sg.reward_ema is None
            assert rec["reward_ema"] is not None   # the observed value
            pool.busy()
            r2 = sg.tick()
            assert r2["reward_ema"] == pytest.approx(r2["reward"])
            return
    pytest.fail("governor never switched under full exploration")


# ------------------------------------------------- docs checker additions

def _load_check_docs():
    p = Path(__file__).resolve().parents[1] / "tools" / "check_docs.py"
    spec = importlib.util.spec_from_file_location("check_docs", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_docs_module_coverage_negative(tmp_path):
    cd = _load_check_docs()
    pkg = tmp_path / "src" / "repro" / "sub"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")       # exempt
    (pkg / "covered.py").write_text("")
    (pkg / "orphan.py").write_text("")
    doc = tmp_path / "docs"
    doc.mkdir()
    (doc / "x.md").write_text("see `sub/covered.py` for details")
    errs = cd.module_coverage_errors(tmp_path, [doc / "x.md"])
    assert len(errs) == 1 and "sub/orphan.py" in errs[0]
    # dotted module references also count as mentions
    (doc / "x.md").write_text("`sub/covered.py` and `repro.sub.orphan`")
    assert cd.module_coverage_errors(tmp_path, [doc / "x.md"]) == []


def test_check_docs_reachability_negative(tmp_path):
    cd = _load_check_docs()
    doc = tmp_path / "docs"
    doc.mkdir()
    (doc / "a.md").write_text("leads to [b](b.md)")
    (doc / "b.md").write_text("terminal")
    (doc / "lost.md").write_text("nobody links here")
    errs = cd.reachability_errors(tmp_path)    # no index at all
    assert errs == ["docs/README.md index page is missing"]
    (doc / "README.md").write_text("start at [a](a.md)")
    errs = cd.reachability_errors(tmp_path)
    assert len(errs) == 1 and "lost.md" in errs[0]     # a,b transitively ok
    (doc / "b.md").write_text("now [lost](lost.md) is linked")
    assert cd.reachability_errors(tmp_path) == []


def test_check_docs_repo_is_clean():
    """The real tree passes all three checks (paths, coverage, reach)."""
    cd = _load_check_docs()
    root = Path(__file__).resolve().parents[1]
    docs = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    assert cd.module_coverage_errors(root, docs) == []
    assert cd.reachability_errors(root) == []
