"""Equivalence tests: set-parallel engine vs. the serial lax.scan oracle.

The engine's contract (core/engine.py): requests to different (tier, set)
commute, so per-set scans in original in-set order must reproduce the
serial simulation EXACTLY on every integer counter, and up to accumulation
order (<= 1e-3 relative) on the float sums.
"""
import itertools
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import address_separation as asep
from repro.core import cache_sim as cs
from repro.core import controller as ctl
from repro.core import engine


def _cfg(conv_sets=8, chips=2, sets_per_chip=4, **kw):
    amap = asep.make_map(conv_sets=conv_sets, num_cache_chips=chips,
                         sets_per_chip=sets_per_chip)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4, **kw)


def _trace(n=2500, span=2048, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, span, size=n).astype(np.uint32),
            rng.random(n) < 0.3,
            rng.integers(0, 3, size=n).astype(np.int32))


def _case_seed(*parts) -> int:
    """Deterministic per-case trace seed (hash() is randomized per run)."""
    return zlib.crc32("/".join(map(str, parts)).encode()) % 1000


def _assert_stats_equal(s_ser: ctl.Stats, s_par: ctl.Stats, ctx=""):
    for f in ctl.Stats._fields:
        a = np.asarray(getattr(s_ser, f))
        b = np.asarray(getattr(s_par, f))
        if f in ctl._INT_FIELDS:
            assert a == b, f"{ctx} {f}: serial={a} parallel={b}"
        else:
            tol = 1e-3 * max(abs(float(a)), 1.0)
            assert abs(float(a) - float(b)) <= tol, \
                f"{ctx} {f}: serial={a} parallel={b}"


@pytest.mark.parametrize("pred,comp", list(itertools.product(
    list(ctl.Predictor), [False, True])))
def test_engine_matches_serial_oracle(pred, comp):
    """Exact Stats equivalence across predictor x compression, warmup>0."""
    cfg = _cfg(predictor=pred, compression=comp)
    addrs, writes, levels = _trace(seed=_case_seed(pred.value, comp))
    warmup = 311
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), warmup)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, warmup)
    _assert_stats_equal(s_ser, s_par, f"{pred.value}/comp={comp}")


def test_engine_conv_only_config():
    """Extended tier disabled: the engine must skip the ext kernels and
    still reproduce the serial stats."""
    amap = asep.make_map(conv_sets=8, num_cache_chips=0, sets_per_chip=0)
    cfg = ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4)
    addrs, writes, levels = _trace(span=512, seed=7)
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), 0)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, 0)
    _assert_stats_equal(s_ser, s_par, "conv-only")


def test_engine_warmup_exceeds_trace():
    """warmup >= trace length zeroes every counter, like the oracle."""
    cfg = _cfg()
    addrs, writes, levels = _trace(n=500, seed=3)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, 500)
    for f in ctl._INT_FIELDS:
        assert int(getattr(s_par, f)) == 0, f


def test_simulate_batch_matches_individual():
    """Batching traces must not change any per-trace result."""
    cfg = _cfg(predictor=ctl.Predictor.BLOOM)
    traces = [(_trace(seed=s)[0], _trace(seed=s)[1], _trace(seed=s)[2], 100)
              for s in (1, 2, 3)]
    batched = engine.simulate_batch(cfg, traces)
    for i, (a, w, l, warm) in enumerate(traces):
        single = engine.simulate_parallel(cfg, a, w, l, warm)
        for f in ctl.Stats._fields:
            got = np.asarray(getattr(batched, f))[i]
            want = np.asarray(getattr(single, f))
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                       err_msg=f"trace {i} field {f}")


def test_run_batch_matches_per_point_run():
    """Sweep-layer regression: run_batch == per-point run, and both equal
    the serial-oracle pipeline on the Stats."""
    pts = [
        cs.RunPoint("kmeans", "BL", 18, 0, 6000),
        cs.RunPoint("kmeans", "BL", 48, 0, 6000),
        cs.RunPoint("cfd", "Morpheus-ALL", 32, 24, 6000),
        cs.RunPoint("histo", "Unified-SM-Mem", 32, 0, 6000),
    ]
    batched = cs.run_batch(pts)
    for pt, rb in zip(pts, batched):
        r1 = cs.run(pt.app, pt.system, n_compute=pt.n_compute,
                    n_cache=pt.n_cache, length=pt.length, seed=pt.seed)
        assert r1.exec_time_s == rb.exec_time_s, pt
        assert r1.ipc == rb.ipc, pt
        # against the serial oracle
        cfg, (a, w, l, warm), n_c, n_k, n_acc = cs._prepare(pt)
        s_ser = ctl.simulate_jit(cfg, jnp.asarray(a), jnp.asarray(w),
                                 jnp.asarray(l), warm)
        _assert_stats_equal(ctl.Stats(*[np.asarray(x) for x in s_ser]),
                            rb.stats, f"{pt.app}/{pt.system}")


def test_run_batch_padding_chunk():
    """A group size that is not a power of two exercises the padded final
    chunk; padded duplicates must not leak into the results."""
    pts = [cs.RunPoint("cfd", "BL", n, 0, 4000) for n in
           (10, 14, 18, 24, 32)]  # 5 points -> chunks of 16? no: [8] pad 3
    res = cs.run_batch(pts)
    assert [r.n_compute for r in res] == [10, 14, 18, 24, 32]
    assert len({r.exec_time_s for r in res}) > 1  # distinct grid points


# ------------------------------------------------------- pallas backend

_pallas_ok, _pallas_why = engine.backend_status("pallas")
needs_pallas = pytest.mark.skipif(not _pallas_ok, reason=_pallas_why)


@needs_pallas
@pytest.mark.parametrize("pred,comp,warmup", list(itertools.product(
    list(ctl.Predictor), [False, True], [0, 311])))
def test_pallas_backend_matches_serial_oracle(pred, comp, warmup):
    """The fused Pallas scan (kernels/engine_scan) must reproduce the
    serial oracle bit-for-bit on integer Stats across the predictor x
    compression x warmup property grid (acceptance criterion)."""
    cfg = _cfg(predictor=pred, compression=comp)
    addrs, writes, levels = _trace(seed=_case_seed(pred.value, comp, warmup))
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), warmup)
    s_pal = engine.simulate_parallel(cfg, addrs, writes, levels, warmup,
                                     backend="pallas")
    _assert_stats_equal(s_ser, s_pal,
                        f"pallas/{pred.value}/comp={comp}/warm={warmup}")


@needs_pallas
def test_pallas_backend_conv_only_config():
    """Extended tier disabled: the Pallas engine runs only the conv kernel
    and still matches the serial stats."""
    amap = asep.make_map(conv_sets=8, num_cache_chips=0, sets_per_chip=0)
    cfg = ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4)
    addrs, writes, levels = _trace(span=512, seed=7)
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), 0)
    s_pal = engine.simulate_parallel(cfg, addrs, writes, levels, 0,
                                     backend="pallas")
    _assert_stats_equal(s_ser, s_pal, "pallas/conv-only")


@needs_pallas
def test_run_batch_backend_threading():
    """RunPoint.backend reaches the engine: pallas and jnp points produce
    identical integer stats and identical derived metrics through the
    whole run_batch pipeline."""
    kw = dict(n_cache=8, length=3000)
    rj = cs.run_batch([cs.RunPoint("cfd", "Morpheus-ALL", 32,
                                   backend="jnp", **kw)])[0]
    rp = cs.run_batch([cs.RunPoint("cfd", "Morpheus-ALL", 32,
                                   backend="pallas", **kw)])[0]
    _assert_stats_equal(rj.stats, rp.stats, "run_batch jnp-vs-pallas")
    assert abs(rj.exec_time_s - rp.exec_time_s) <= 1e-3 * rj.exec_time_s


def test_backend_resolution():
    """Unknown / unsupported backends fail with an explanatory error, not
    a Pallas traceback; the default resolves to a supported backend."""
    b = engine.resolve_backend(None)
    assert b in engine.BACKENDS and engine.backend_status(b)[0]
    with pytest.raises(engine.BackendError, match="unknown backend"):
        engine.resolve_backend("cuda")


# ------------------------------------------------------- pack edge cases

def test_pack_empty_trace():
    """A zero-length trace packs to zero-width buckets and simulates to
    all-zero stats on both backends."""
    cfg = _cfg()
    empty = (np.zeros(0, np.uint32), np.zeros(0, bool), np.zeros(0, np.int32))
    pt = engine.pack(cfg, [(empty[0], empty[1], empty[2], 0)])
    assert pt.conv_tag.shape[2] == 0 and pt.ext_tag.shape[2] == 0
    stats = engine.simulate_batch(cfg, [(*empty, 0)])
    for f in ctl.Stats._fields:
        assert float(np.asarray(getattr(stats, f))[0]) == 0.0, f


def test_pack_single_set_trace():
    """All requests landing in one conventional set: one dense row, the
    other rows fully padded, and the engine still matches the oracle."""
    cfg = _cfg()
    total = cfg.amap.total_sets
    n = 100
    addrs = (np.arange(n, dtype=np.uint32) * total + 2)  # gset == 2, conv
    writes = np.zeros(n, bool)
    levels = np.zeros(n, np.int32)
    pt = engine.pack(cfg, [(addrs, writes, levels, 0)])
    assert pt.conv_active[0, 2].sum() == n
    assert pt.conv_active[0].sum() == n          # every other row padding
    assert pt.ext_tag.shape[2] == 0              # ext tier saw nothing
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), 0)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, 0)
    _assert_stats_equal(s_ser, s_par, "single-set")


def test_pack_all_padding_rows_are_noops():
    """Sets with zero requests are provable no-ops: adding a second trace
    that only touches other sets must not change the first trace's row."""
    cfg = _cfg()
    total = cfg.amap.total_sets
    t1 = ((np.arange(40, dtype=np.uint32) * total + 1),
          np.zeros(40, bool), np.zeros(40, np.int32), 0)
    t2 = ((np.arange(64, dtype=np.uint32) * total + 3),
          np.zeros(64, bool), np.zeros(64, np.int32), 0)
    batched = engine.simulate_batch(cfg, [t1, t2])
    single = engine.simulate_batch(cfg, [t1])
    for f in ctl._INT_FIELDS:
        assert (np.asarray(getattr(batched, f))[0]
                == np.asarray(getattr(single, f))[0]), f


@pytest.mark.parametrize("n,expect", [(15, 16), (16, 16), (17, 32),
                                      (64, 64), (65, 128)])
def test_pack_pow2_padding_boundary(n, expect):
    """L lands exactly on the pow2 bucket when the max per-set count is a
    power of two; one extra request doubles the bucket."""
    cfg = _cfg()
    total = cfg.amap.total_sets
    addrs = (np.arange(n, dtype=np.uint32) * total)  # all -> set 0 (conv)
    pt = engine.pack(cfg, [(addrs, np.zeros(n, bool),
                            np.zeros(n, np.int32), 0)])
    assert pt.conv_tag.shape[2] == expect
    assert pt.conv_active[0, 0].sum() == n
