"""Equivalence tests: set-parallel engine vs. the serial lax.scan oracle.

The engine's contract (core/engine.py): requests to different (tier, set)
commute, so per-set scans in original in-set order must reproduce the
serial simulation EXACTLY on every integer counter, and up to accumulation
order (<= 1e-3 relative) on the float sums.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import address_separation as asep
from repro.core import cache_sim as cs
from repro.core import controller as ctl
from repro.core import engine


def _cfg(conv_sets=8, chips=2, sets_per_chip=4, **kw):
    amap = asep.make_map(conv_sets=conv_sets, num_cache_chips=chips,
                         sets_per_chip=sets_per_chip)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4, **kw)


def _trace(n=2500, span=2048, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, span, size=n).astype(np.uint32),
            rng.random(n) < 0.3,
            rng.integers(0, 3, size=n).astype(np.int32))


def _assert_stats_equal(s_ser: ctl.Stats, s_par: ctl.Stats, ctx=""):
    for f in ctl.Stats._fields:
        a = np.asarray(getattr(s_ser, f))
        b = np.asarray(getattr(s_par, f))
        if f in ctl._INT_FIELDS:
            assert a == b, f"{ctx} {f}: serial={a} parallel={b}"
        else:
            tol = 1e-3 * max(abs(float(a)), 1.0)
            assert abs(float(a) - float(b)) <= tol, \
                f"{ctx} {f}: serial={a} parallel={b}"


@pytest.mark.parametrize("pred,comp", list(itertools.product(
    list(ctl.Predictor), [False, True])))
def test_engine_matches_serial_oracle(pred, comp):
    """Exact Stats equivalence across predictor x compression, warmup>0."""
    cfg = _cfg(predictor=pred, compression=comp)
    addrs, writes, levels = _trace(seed=hash((pred.value, comp)) % 1000)
    warmup = 311
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), warmup)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, warmup)
    _assert_stats_equal(s_ser, s_par, f"{pred.value}/comp={comp}")


def test_engine_conv_only_config():
    """Extended tier disabled: the engine must skip the ext kernels and
    still reproduce the serial stats."""
    amap = asep.make_map(conv_sets=8, num_cache_chips=0, sets_per_chip=0)
    cfg = ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4)
    addrs, writes, levels = _trace(span=512, seed=7)
    s_ser = ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                         jnp.asarray(levels), 0)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, 0)
    _assert_stats_equal(s_ser, s_par, "conv-only")


def test_engine_warmup_exceeds_trace():
    """warmup >= trace length zeroes every counter, like the oracle."""
    cfg = _cfg()
    addrs, writes, levels = _trace(n=500, seed=3)
    s_par = engine.simulate_parallel(cfg, addrs, writes, levels, 500)
    for f in ctl._INT_FIELDS:
        assert int(getattr(s_par, f)) == 0, f


def test_simulate_batch_matches_individual():
    """Batching traces must not change any per-trace result."""
    cfg = _cfg(predictor=ctl.Predictor.BLOOM)
    traces = [(_trace(seed=s)[0], _trace(seed=s)[1], _trace(seed=s)[2], 100)
              for s in (1, 2, 3)]
    batched = engine.simulate_batch(cfg, traces)
    for i, (a, w, l, warm) in enumerate(traces):
        single = engine.simulate_parallel(cfg, a, w, l, warm)
        for f in ctl.Stats._fields:
            got = np.asarray(getattr(batched, f))[i]
            want = np.asarray(getattr(single, f))
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6,
                                       err_msg=f"trace {i} field {f}")


def test_run_batch_matches_per_point_run():
    """Sweep-layer regression: run_batch == per-point run, and both equal
    the serial-oracle pipeline on the Stats."""
    pts = [
        cs.RunPoint("kmeans", "BL", 18, 0, 6000),
        cs.RunPoint("kmeans", "BL", 48, 0, 6000),
        cs.RunPoint("cfd", "Morpheus-ALL", 32, 24, 6000),
        cs.RunPoint("histo", "Unified-SM-Mem", 32, 0, 6000),
    ]
    batched = cs.run_batch(pts)
    for pt, rb in zip(pts, batched):
        r1 = cs.run(pt.app, pt.system, n_compute=pt.n_compute,
                    n_cache=pt.n_cache, length=pt.length, seed=pt.seed)
        assert r1.exec_time_s == rb.exec_time_s, pt
        assert r1.ipc == rb.ipc, pt
        # against the serial oracle
        cfg, (a, w, l, warm), n_c, n_k, n_acc = cs._prepare(pt)
        s_ser = ctl.simulate_jit(cfg, jnp.asarray(a), jnp.asarray(w),
                                 jnp.asarray(l), warm)
        _assert_stats_equal(ctl.Stats(*[np.asarray(x) for x in s_ser]),
                            rb.stats, f"{pt.app}/{pt.system}")


def test_run_batch_padding_chunk():
    """A group size that is not a power of two exercises the padded final
    chunk; padded duplicates must not leak into the results."""
    pts = [cs.RunPoint("cfd", "BL", n, 0, 4000) for n in
           (10, 14, 18, 24, 32)]  # 5 points -> chunks of 16? no: [8] pad 3
    res = cs.run_batch(pts)
    assert [r.n_compute for r in res] == [10, 14, 18, 24, 32]
    assert len({r.exec_time_s for r in res}) > 1  # distinct grid points
