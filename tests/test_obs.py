"""Observability layer tests (ISSUE 8): spans, metrics, decision
provenance, and the guarantees around them.

Headline properties (acceptance):

  * disabled observability is a true no-op: ``obs.span`` returns the
    shared null singleton, and an obs-enabled ``simulate_online`` run is
    bit-identical (telemetry rows, decision sequence, Stats) to a
    disabled one on the jnp AND pallas engine backends;
  * every governor decision path — greedy, explore, hint, phase_jump,
    ctx_reentry, churn_reset, phase_shift — emits exactly one correctly
    typed ``DecisionEvent``, and every split switch in an online run has
    exactly one attributed switch event (the audit invariant);
  * the autotuner's trajectory bytes don't change with obs enabled
    (the golden CRC guarantee extends under instrumentation);
  * ``TelemetryLog`` exports oldest -> newest even after the ring wraps;
  * bench documents round-trip schema v2 (optional ``counters``) while
    v1 files stay valid; ``tools/obs_report.py`` renders a bundle.
"""
import json
import subprocess
import sys
import zlib
from pathlib import Path

import numpy as np
import pytest

from repro import obs
from repro.autotune import Tuner, gov_space, make_agent
from repro.core import engine
from repro.obs.decision import TRIGGERS, DecisionEvent
from repro.obs.metrics import Registry
from repro.obs.trace import NULL_SPAN, Tracer
from repro.runtime import Governor, GovernorConfig, simulate_online
from repro.runtime.telemetry import FIELDS, EpochRecord, TelemetryLog
from repro.workloads.serving import SLOBudgeter

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import bench_compare  # noqa: E402
import bench_schema as bs  # noqa: E402

_pallas_ok, _pallas_why = engine.backend_status("pallas")


@pytest.fixture(autouse=True)
def _obs_off():
    """Every test starts and ends with observability disabled."""
    obs.disable()
    yield
    obs.disable()


def _record(epoch, **kw):
    base = dict(epoch=epoch, pos=epoch * 100, app="x", n_compute=32,
                n_cache=36, requests=100, hit_rate=0.5,
                ext_occupancy=0.5, pred_accuracy=0.9, bytes_saved=0.0,
                ipc=1.0, exec_time_s=1e-4, reward=1.0)
    base.update(kw)
    return EpochRecord(**base)


# ------------------------------------------------------------------ spans

def test_disabled_span_is_shared_null_singleton():
    assert not obs.enabled()
    assert obs.span("a", k=1) is NULL_SPAN
    assert obs.span("b") is NULL_SPAN
    with obs.span("c", x=2) as sp:
        sp.set(y=3)          # must be a silent no-op
    obs.instant("d", v=1)    # likewise
    obs.count("nothing", 5)
    assert obs.tracer() is None and obs.metrics_registry() is None


def test_tracer_deterministic_with_injected_clock():
    ticks = iter(range(0, 100_000, 1_000))   # ns
    t = Tracer(clock=lambda: next(ticks))
    with t.span("outer", layer="runtime"):
        with t.span("inner") as sp:
            sp.set(rows=4)
    doc = json.loads(t.to_json())
    assert doc["displayTimeUnit"] == "ms"
    inner, outer = doc["traceEvents"]        # inner completes first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["ph"] == outer["ph"] == "X"
    # clock ticks: outer t0=0, inner t0=1000, inner t1=2000, outer
    # t1=3000 ns -> microseconds
    assert (inner["ts"], inner["dur"]) == (1.0, 1.0)
    assert (outer["ts"], outer["dur"]) == (0.0, 3.0)
    assert inner["args"] == {"rows": 4}
    assert outer["args"] == {"layer": "runtime"}


def test_tracer_instant_and_summary(tmp_path):
    ticks = iter(range(0, 100_000, 1_000))
    t = Tracer(clock=lambda: next(ticks))
    with t.span("s"):
        pass
    t.instant("mark", why="because")
    ev = t.events[-1]
    assert ev["ph"] == "i" and ev["s"] == "g" and ev["name"] == "mark"
    s = t.summary()
    assert s["s"]["count"] == 1 and s["s"]["total_us"] == 1.0
    p = t.save(tmp_path / "trace.json")
    assert "traceEvents" in json.loads(p.read_text())


# ---------------------------------------------------------------- metrics

def test_registry_counter_gauge_histogram_exposition():
    r = Registry()
    r.counter("engine_dispatches", "dispatches issued").inc(
        3, path="epoch")
    r.counter("engine_dispatches").inc(2, path="fleet")
    r.gauge("slo_attainment").set(0.75, tenant="a")
    h = r.histogram("span_ns", buckets=(10, 100))
    h.observe(5)
    h.observe(50)
    h.observe(5000)
    text = r.to_prometheus()
    assert 'morpheus_engine_dispatches_total{path="epoch"} 3' in text
    assert 'morpheus_engine_dispatches_total{path="fleet"} 2' in text
    assert 'morpheus_slo_attainment{tenant="a"} 0.75' in text
    assert 'morpheus_span_ns_bucket{le="10"} 1' in text
    assert 'morpheus_span_ns_bucket{le="100"} 2' in text
    assert 'morpheus_span_ns_bucket{le="+Inf"} 3' in text
    assert "morpheus_span_ns_count 3" in text
    snap = r.snapshot()
    names = {m["name"] for m in snap["metrics"]}
    assert {"engine_dispatches", "slo_attainment", "span_ns"} <= names
    json.dumps(snap)    # JSON-clean


def test_registry_save_formats(tmp_path):
    r = Registry()
    r.counter("epochs").inc(7)
    j = r.save(tmp_path / "m.json")
    assert json.loads(j.read_text())["metrics"][0]["name"] == "epochs"
    p = r.save(tmp_path / "m.prom")
    assert "morpheus_epochs_total 7" in p.read_text()


def test_module_helpers_route_to_active_registry():
    obs.enable(trace=False)
    obs.count("engine_dispatches", 2, path="epoch")
    obs.set_gauge("slo_attainment", 0.5)
    obs.observe("span_ns", 42.0)
    c = obs.bench_counters()
    assert c["dispatches"] == 2
    assert c["compiles"] >= 0 and c["epochs"] == 0
    obs.disable()
    # helpers silently drop once deactivated
    obs.count("engine_dispatches", 99)
    assert obs.metrics_registry() is None


def test_compile_hook_counts_real_xla_compiles():
    import jax
    import jax.numpy as jnp
    obs.enable(trace=False)
    f = jax.jit(lambda x: x * 3 + 1)
    x = jnp.arange(7)
    f(x).block_until_ready()
    n1 = obs.bench_counters()["compiles"]
    assert n1 >= 1, "compile hook missed a fresh XLA build"
    f(x).block_until_ready()    # cached: no new executable
    assert obs.bench_counters()["compiles"] == n1


# -------------------------------------------------------------- telemetry

def test_telemetry_export_is_oldest_first_after_wrap(tmp_path):
    log = TelemetryLog(capacity=8)
    for i in range(20):
        log.append(_record(i))
    assert len(log) == 8 and log.total == 20
    epochs = [r.epoch for r in log.records()]
    assert epochs == list(range(12, 20)), \
        "wrapped export must start at the oldest held record"
    rows = log.to_csv(tmp_path / "t.csv").read_text().splitlines()
    assert rows[0].split(",")[0] == "epoch"
    assert [int(r.split(",")[0]) for r in rows[1:]] == epochs
    assert [r["epoch"] for r in json.loads(log.to_json())] == epochs


def test_telemetry_tail_zero_is_empty():
    log = TelemetryLog(capacity=4)
    for i in range(3):
        log.append(_record(i))
    assert log.tail(0) == []
    assert [r.epoch for r in log.tail(2)] == [1, 2]
    assert len(log.tail(99)) == 3


def test_epoch_record_has_decision_column():
    assert FIELDS[-1] == "decision"
    assert _record(0).decision == ""


# ----------------------------------------------------- decision provenance

def test_decision_event_contract():
    ev = DecisionEvent(epoch=3, trigger="hint", from_split=(32, 36),
                       to_split=(28, 40), epsilon=0.2, hint=1)
    assert ev.switched and ev.compact() == "hint:(32|36)->(28|40)"
    held = DecisionEvent(epoch=3, trigger="churn_reset",
                         from_split=(32, 36), to_split=(32, 36),
                         epsilon=0.2)
    assert not held.switched and held.compact() == "churn_reset"
    json.dumps(ev.to_dict())
    assert ev.to_dict()["from_split"] == [32, 36]
    with pytest.raises(AssertionError):
        DecisionEvent(epoch=0, trigger="vibes", from_split=0,
                      to_split=1, epsilon=0.0)


def _drive(gov, reward_fn, epochs, hint=0, sig=None, ctx=None):
    for _ in range(epochs):
        if ctx is not None:
            gov.set_context(ctx)
        kw = {} if sig is None else {"signature": sig}
        gov.observe(reward_fn(gov.current), hint=hint, **kw)
        gov.decide()


def _triggers(gov):
    return [e.trigger for e in gov.decisions]


def test_greedy_and_explore_paths_emit_typed_events():
    cands = [(n, 68 - n) for n in (10, 20, 30, 40, 50, 60)]
    peak = {c: 100.0 - abs(c[0] - 40) for c in cands}
    gov = Governor(cands, GovernorConfig(seed=3, warm_epochs=0))
    _drive(gov, lambda c: peak[c], 60)
    assert gov.current == (40, 28)
    trig = _triggers(gov)
    assert "greedy" in trig, trig
    assert "explore" in trig, trig     # epsilon draws fired along the way
    # audit invariant: one attributed switch event per switch
    switch_events = [e for e in gov.decisions if e.switched]
    assert len(switch_events) == gov.switches
    assert all(e.trigger in ("greedy", "explore", "hint", "phase_jump",
                             "ctx_reentry") for e in switch_events)
    # estimates consulted at decision time ride along
    assert any(e.estimates for e in switch_events)


def test_hint_path_emits_hint_event():
    gov = Governor(list(range(5)),
                   GovernorConfig(seed=0, warm_epochs=0), initial=2)
    _drive(gov, lambda c: 10.0, 12, hint=+1)
    hints = [e for e in gov.decisions if e.trigger == "hint"]
    assert hints and all(e.hint == +1 and e.switched for e in hints)


def test_phase_shift_and_phase_jump_events():
    gov = Governor(list(range(6)), GovernorConfig(seed=2, warm_epochs=0))
    _drive(gov, lambda c: 50.0 - 5 * c, 40, sig=0.15)
    _drive(gov, lambda c: 30.0 + 5 * c, 60, sig=0.90)
    _drive(gov, lambda c: 50.0 - 5 * c, 3, sig=0.15)   # revisit phase A
    trig = _triggers(gov)
    # a re-entry records the reset (phase_shift) AND the jump it served
    assert trig.count("phase_shift") == gov.phase_shifts
    jumps = [e for e in gov.decisions if e.trigger == "phase_jump"]
    assert jumps, "phase-memory re-entry recorded no phase_jump event"
    assert all(e.switched for e in jumps)
    shifts = [e for e in gov.decisions if e.trigger == "phase_shift"]
    assert shifts and all(not e.switched for e in shifts)


def test_churn_reset_and_ctx_reentry_events():
    gov = Governor(list(range(6)), GovernorConfig(seed=1, warm_epochs=0))
    _drive(gov, lambda c: 50.0 - 5 * c, 40, ctx=0b11)
    _drive(gov, lambda c: 30.0 + 5 * c, 50, ctx=0b01)  # churn 1
    _drive(gov, lambda c: 50.0 - 5 * c, 2, ctx=0b11)   # churn 2 + re-entry
    resets = [e for e in gov.decisions if e.trigger == "churn_reset"]
    assert len(resets) == gov.churn_resets == 2
    assert all(not e.switched and e.ctx is not None for e in resets)
    re = [e for e in gov.decisions if e.trigger == "ctx_reentry"]
    assert len(re) == 1 and re[0].switched and re[0].ctx == 0b11


def test_every_trigger_name_is_exercised_above():
    """The taxonomy is closed: tests above cover every member, so a new
    trigger string must come with a test."""
    covered = {"greedy", "explore", "hint", "phase_jump", "ctx_reentry",
               "churn_reset", "phase_shift"}
    assert covered == set(TRIGGERS)


# ------------------------------------------------- online run provenance

def _online(**kw):
    return simulate_online(("p-bfs", "spmv", "p-bfs"), "Morpheus-ALL",
                           length=12_000, epoch_len=1_500, seed=3, **kw)


def test_online_run_attributes_every_switch():
    r = _online()
    assert r.decisions, "online run recorded no decision events"
    switch_events = [e for e in r.decisions if e.switched]
    assert len(switch_events) == r.switches
    assert all(e.replica for e in r.decisions)
    # flush cost paid by each switch is attributed to its event
    assert sum(e.flush_writebacks for e in r.decisions) == \
        sum(rec.flush_writebacks for rec in r.records)
    # the telemetry decision column compacts the same events
    recs_with_switch = [rec for rec in r.records if rec.switched]
    for rec in recs_with_switch:
        assert "->" in rec.decision, rec
    assert sum("->" in (rec.decision or "") for rec in r.records) == \
        len(switch_events)


@pytest.mark.parametrize("backend", [
    "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        not _pallas_ok, reason=_pallas_why)),
])
def test_enabled_obs_is_bit_identical(backend):
    base = _online(backend=backend)
    obs.enable()
    on = _online(backend=backend)
    obs.disable()
    assert [rec.to_dict() for rec in base.records] == \
        [rec.to_dict() for rec in on.records]
    assert [e.to_dict() for e in base.decisions] == \
        [e.to_dict() for e in on.decisions]
    assert (base.ipc, base.switches, base.converged_split) == \
        (on.ipc, on.switches, on.converged_split)


def test_online_run_emits_trace_instants_and_counters():
    obs.enable()
    r = _online()
    t = obs.tracer()
    instants = [e for e in t.events if e["name"] == "governor.decision"]
    assert len(instants) == len(r.decisions)
    names = {e["name"] for e in t.events}
    assert "governor.decide" in names
    c = obs.bench_counters()
    assert c["dispatches"] == len(r.records) == c["epochs"]
    assert c["device_get_bytes"] > 0
    assert c["flush_writebacks"] == \
        sum(rec.flush_writebacks for rec in r.records)


# -------------------------------------------- trajectory byte-determinism

class _SynthObjective:
    def __init__(self, space):
        self.space = space

    def evaluate(self, configs):
        return [-sum((2 * i - 3) ** 2 for i in self.space.encode(c))
                for c in configs]

    def describe(self):
        return {"objective": "synth"}


def _run_tuner(path):
    space = gov_space()
    Tuner(space, _SynthObjective(space),
          make_agent("ga", space, seed=0, pop=5),
          trajectory_path=path).run(4)
    return Path(path).read_bytes()


def test_tuner_trajectory_bytes_identical_under_obs(tmp_path):
    off = _run_tuner(tmp_path / "off.jsonl")
    obs.enable(inspect=True)       # full stack incl. the cache microscope
    on = _run_tuner(tmp_path / "on.jsonl")
    spans = [e for e in obs.tracer().events
             if e["name"] == "tuner.generation"]
    obs.disable()
    assert zlib.crc32(off) == zlib.crc32(on) and off == on
    assert len(spans) == 4
    assert spans[0]["args"]["agent"] == "ga"


# ----------------------------------------------------------- SLO budgeter

def test_slo_budgeter_tracks_attainment():
    b = SLOBudgeter(slo_ms=1.0)
    assert b.attainment() == 1.0
    b.observe(ns_per_lookup=100.0, lookups=5_000, requests=10)   # 0.5 ms
    b.observe(ns_per_lookup=100.0, lookups=20_000, requests=10)  # 2.0 ms
    assert b.rounds_observed == 2 and b.rounds_met == 1
    assert b.attainment() == 0.5
    b.observe(ns_per_lookup=100.0, lookups=0, requests=0)        # idle
    assert b.rounds_observed == 2


# --------------------------------------------------------- bench schema v2

def test_bench_schema_v2_counters_roundtrip(tmp_path):
    p = bs.write_bench("unit", "quick", {"step warm": 1.0},
                       counters={"dispatches": 12, "epochs": 4},
                       path=tmp_path / "b.json")
    doc = bs.load_bench(p)
    assert doc["schema"] == 2
    assert doc["counters"] == {"dispatches": 12, "epochs": 4}
    assert bench_compare.validate([p]) == 0


def test_bench_schema_v1_still_valid(tmp_path):
    p = bs.write_bench("unit", "quick", {"step warm": 1.0},
                       path=tmp_path / "b.json")
    doc = json.loads(p.read_text())
    doc["schema"] = 1                    # what a committed v1 file says
    doc.pop("counters", None)
    p.write_text(json.dumps(doc))
    assert bs.load_bench(p)["schema"] == 1
    bad = dict(doc, schema=1, counters={"dispatches": 1})
    with pytest.raises(AssertionError):
        bs.validate(bad)                 # counters require schema >= 2


def test_bench_path_env_override(tmp_path, monkeypatch):
    target = tmp_path / "redirect.json"
    monkeypatch.setenv("REPRO_BENCH_PATH", str(target))
    p = bs.write_bench("unit", "quick", {"step warm": 1.0})
    assert p == target and target.exists()


# ----------------------------------------- cache microscope (ISSUE 9)

def _stats_ints(stats):
    return [int(np.asarray(v)) for v in stats]


@pytest.mark.parametrize("backend", [
    "jnp",
    pytest.param("pallas", marks=pytest.mark.skipif(
        not _pallas_ok, reason=_pallas_why)),
])
def test_enabled_introspection_is_bit_identical(backend):
    """Full microscope on (per-epoch state snapshots) changes NO
    simulator output: integer Stats, telemetry rows and the decision
    sequence stay bit-identical on both backends."""
    base = _online(backend=backend)
    obs.enable(trace=False, metrics=False, inspect=True)
    on = _online(backend=backend)
    snaps = obs.inspector().snapshots
    obs.disable()
    assert snaps, "microscope recorded no snapshots"
    assert _stats_ints(base.stats) == _stats_ints(on.stats)
    assert [rec.to_dict() for rec in base.records] == \
        [rec.to_dict() for rec in on.records]
    assert [e.to_dict() for e in base.decisions] == \
        [e.to_dict() for e in on.decisions]
    assert (base.ipc, base.switches, base.converged_split) == \
        (on.ipc, on.switches, on.converged_split)


def test_snapshot_counter_and_decode_sanity():
    obs.enable(trace=False, metrics=True, inspect=True)
    r = _online()
    snaps = obs.inspector().snapshots
    c = obs.bench_counters()
    obs.disable()
    assert len(snaps) == len(r.records) == c["snapshots"]
    assert [s.epoch for s in snaps] == sorted(s.epoch for s in snaps)
    for s in snaps:
        assert 0.0 <= s.conv_occupancy <= 1.0
        assert 0.0 <= s.ext_occupancy <= 1.0
        assert 0.0 <= s.byte_util <= 1.0
        assert 0.0 <= s.bloom_fill <= 1.0
        assert 0.0 <= s.bloom_fp_rate <= 1.0
        assert s.expansion >= 1.0          # BDI never inflates
        if s.conv_occupancy > 0:
            # occupancy = valid / (sets * ways): recover the way count
            ways = sum(s.conv_set_occ) / (s.conv_occupancy
                                          * len(s.conv_set_occ))
            assert ways == pytest.approx(round(ways)) and ways >= 1
    # occupancy only grows on this single-phase-dominated stream prefix
    assert snaps[-1].conv_occupancy >= snaps[0].conv_occupancy
    json.dumps(snaps[-1].to_dict())        # export is JSON-clean


def test_inspect_every_strides_snapshots():
    obs.enable(trace=False, metrics=False, inspect=True, inspect_every=3)
    r = _online()
    snaps = obs.inspector().snapshots
    obs.disable()
    assert [s.epoch for s in snaps] == \
        [e for e in range(len(r.records)) if e % 3 == 0]


def test_residency_sums_to_valid_blocks_every_epoch():
    """Per-tenant residency (owners recovered from block addresses) must
    account for every valid block in both tiers, every epoch."""
    from repro.core import cache_sim as cs
    from repro.workloads import tenancy
    wl = tenancy.make_workload("cfd,kmeans", length=9_000, n_cores=32,
                               arrival="det:2e6", seed=0,
                               ws_scale=1.0 / cs.SIM_SCALE)
    obs.enable(trace=False, metrics=False, inspect=True)
    simulate_online(wl, "Morpheus-ALL", epoch_len=1_500)
    snaps = obs.inspector().snapshots
    obs.disable()
    assert snaps
    names = {t.name for t in wl.tenants}
    for s in snaps:
        total = sum(s.conv_set_occ) + sum(s.ext_set_occ)
        assert sum(s.residency.values()) == total, \
            f"epoch {s.epoch}: residency does not account for all blocks"
        assert set(s.residency) <= names
    assert any(len(s.residency) == 2 for s in snaps), \
        "both tenants should hold residency at some epoch"


def test_inspector_caps_and_drops():
    from repro.obs.inspect import Inspector, Snapshot
    ins = Inspector(max_snapshots=2)
    for i in range(5):
        ins.record(Snapshot(epoch=i, pos=i))
    assert len(ins.snapshots) == 2 and ins.dropped == 3
    assert ins.to_json()["dropped"] == 3


# ------------------------------------------------------- stream profiler

def test_reuse_histogram_mass_invariant():
    from repro.obs import profile as prof
    rng = np.random.default_rng(0)
    for addrs in ([], [7], [7, 7, 7], list(range(100)),
                  rng.integers(0, 50, 1_000)):
        h = prof.reuse_histogram(addrs)
        assert h["mass"] == h["cold"] + sum(h["bins"]) == len(addrs)


def test_reuse_distances_exact_small_cases():
    from repro.obs import profile as prof
    # 1 1: re-touch distance 0; 1 2 1: one distinct block in between
    assert prof.reuse_distances([1, 1]).tolist() == [prof.COLD, 0]
    assert prof.reuse_distances([1, 2, 1]).tolist() == \
        [prof.COLD, prof.COLD, 1]
    assert prof.reuse_distances([1, 2, 3, 1, 2]).tolist() == \
        [prof.COLD, prof.COLD, prof.COLD, 2, 2]
    h = prof.reuse_histogram([1, 1, 1])
    assert h["cold"] == 1 and h["bins"][0] == 2     # distance-0 bin


def test_wss_curve_and_per_tenant_profile():
    from repro.obs import profile as prof
    addrs = [1, 2, 1, 3, 2, 4]
    tid = [0, 1, 0, 1, 1, 0]
    p = prof.profile_trace(addrs, tenant_id=tid, names=["a", "b"])
    assert p["wss"]["footprint_blocks"] == 4
    assert p["wss"]["distinct_blocks"][-1] == 4
    assert sorted(p["tenants"]) == ["a", "b"]
    # per-tenant masses sum to the global mass
    assert sum(t["reuse"]["mass"] for t in p["tenants"].values()) == \
        p["reuse"]["mass"] == len(addrs)
    assert p["tenants"]["a"]["wss"]["footprint_blocks"] == 2  # {1, 4}


# ------------------------------------------------------- fairness gauge

def test_jains_index_exact_unity_cases():
    from repro.runtime.telemetry import jains_index
    assert jains_index([]) == 1.0
    assert jains_index([3.7]) == 1.0                 # K=1: exactly 1.0
    assert jains_index([0.4] * 8) == 1.0             # identical tenants
    assert jains_index([0.0, 0.0]) == 1.0            # all-idle epoch
    assert jains_index([1.0, 0.0]) == pytest.approx(0.5)
    # bounds: 1/n <= J <= 1
    xs = [5.0, 1.0, 0.5, 0.25]
    assert 1 / len(xs) <= jains_index(xs) < 1.0


def test_fairness_column_in_epoch_records():
    from repro.core import cache_sim as cs
    from repro.workloads import tenancy
    assert "fairness" in FIELDS and FIELDS[-1] == "decision"
    r = _online()                    # single tenant: exactly 1.0
    assert all(rec.fairness == 1.0 for rec in r.records)
    wl = tenancy.make_workload("cfd,kmeans", length=9_000, n_cores=32,
                               arrival="det:2e6", seed=0,
                               ws_scale=1.0 / cs.SIM_SCALE)
    m = simulate_online(wl, "Morpheus-ALL", epoch_len=1_500)
    assert all(0.0 < rec.fairness <= 1.0 for rec in m.records)


def test_fairness_gauge_registered():
    obs.enable(trace=False, metrics=True)
    _online()
    text = obs.metrics_registry().to_prometheus()
    obs.disable()
    assert "morpheus_fairness_jain" in text


def test_decision_events_carry_summary():
    r = _online()
    for e in r.decisions:
        assert {"hit_rate", "ext_occupancy", "fairness",
                "reward"} <= set(e.summary)
        assert e.to_dict()["summary"]["fairness"] == e.summary["fairness"]


# ------------------------------------------------- pool event recorder

def _pool(chips=2):
    from repro.serving.paged_kv import MorpheusPagePool, PoolConfig
    return MorpheusPagePool(PoolConfig(conv_sets=16, ext_sets_per_chip=8,
                                       num_cache_chips=chips, ways=2))


def test_pool_recorder_logs_and_is_pure(tmp_path):
    from repro.serving import paged_kv as pk
    from repro.workloads import corpus
    keys = np.arange(1, 25, dtype=np.uint32)
    ref = _pool()
    ref.lookup_batch(keys)
    ref.lookup_batch(keys)
    pool = _pool()
    rec = pool.attach_recorder()
    pool.lookup_batch(keys)
    pool.lookup_batch(keys)
    # pure logging: stats identical with and without the recorder
    assert pool.stats == ref.stats
    c = rec.counts()
    assert c["lookup"] == 2 * len(keys)
    assert c["insert"] > 0
    # every insert/evict key routes to a real set (inverse key mapping)
    ks, ev, tiers = rec.arrays()
    assert set(np.unique(ev)) <= {pk.EV_LOOKUP, pk.EV_INSERT, pk.EV_EVICT}
    p = rec.save(tmp_path / "pool.npz")
    addrs, writes, levels, meta = corpus.load_trace(p)
    assert corpus.validate_trace(p) == []
    assert meta["extra"]["kind"] == "pool_events"
    assert meta["extra"]["events"] == c
    assert int(writes.sum()) == c["insert"] + c["evict"]


def test_pool_recorder_survives_reconfigure():
    from repro.serving.paged_kv import EV_EVICT
    pool = _pool(chips=2)
    rec = pool.attach_recorder()
    pool.lookup_batch(np.arange(1, 25, dtype=np.uint32))
    resident = sum(len(k) for k in pool.resident_keys())
    evicts_before = rec.counts()["evict"]
    flushed = pool.reconfigure(1)
    assert pool.recorder is rec, "recorder must survive reconfigure"
    assert flushed == resident
    assert rec.counts()["evict"] == evicts_before + resident, \
        "a mode transition must log one evict per flushed page"


def test_pool_recorder_ring_wraps_oldest_first():
    from repro.serving.paged_kv import EV_LOOKUP, TraceRecorder
    rec = TraceRecorder(capacity=8)
    rec.record(EV_LOOKUP, np.arange(20, dtype=np.uint32), 0)
    ks, _, _ = rec.arrays()
    assert rec.total == 20 and len(rec) == 8
    assert ks.tolist() == list(range(12, 20)), "export must be oldest-first"


def test_pool_content_snapshot_residency():
    from repro.obs.inspect import Inspector
    pool = _pool()
    keys = np.arange(1, 25, dtype=np.uint32)
    pool.lookup_batch(keys)
    ins = Inspector()
    for k in keys[:10]:
        ins.note_owner(int(k), "tenantA")
    snap = pool.content_snapshot(epoch=3, owners=ins.owners)
    valid = sum(snap.conv_set_occ) + sum(snap.ext_set_occ)
    assert sum(snap.residency.values()) == valid
    assert snap.residency.get("tenantA", 0) > 0
    assert "?" in snap.residency          # un-noted keys stay visible
    assert snap.pos == pool.stats.lookups


# --------------------------------------------------------------- reporter

def test_obs_report_heatmap_and_filters(tmp_path):
    obs.enable(trace=True, metrics=False, inspect=True)
    _online()
    ins_p = obs.inspector().save(tmp_path / "inspect.json")
    trace_p = obs.tracer().save(tmp_path / "trace.json")
    obs.disable()
    tool = str(ROOT / "tools" / "obs_report.py")
    out = subprocess.run(
        [sys.executable, tool, "heatmap", str(ins_p),
         "--csv-prefix", str(tmp_path / "hm"),
         "--html", str(tmp_path / "hm.html")],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "set occupancy over epochs" in out.stdout
    assert (tmp_path / "hm_occupancy.csv").exists()
    assert (tmp_path / "hm.html").exists()
    # decision-trail selectors
    out = subprocess.run(
        [sys.executable, tool, "--trace", str(trace_p), "--decisions",
         "--filter", "trigger=explore", "--epochs", "0:99"],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "trigger=explore" in out.stdout
    # unknown inspect schema exits 2, no traceback
    bad = dict(json.loads(ins_p.read_text()), schema=99)
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps(bad))
    r = subprocess.run([sys.executable, tool, "heatmap", str(bad_p)],
                       capture_output=True, text=True)
    assert r.returncode == 2 and "Traceback" not in r.stderr


def test_obs_report_unknown_metrics_schema_exits_2(tmp_path):
    p = tmp_path / "m.json"
    p.write_text(json.dumps({"schema": 9, "metrics": []}))
    r = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"),
         "--metrics", str(p)], capture_output=True, text=True)
    assert r.returncode == 2 and "Traceback" not in r.stderr
    assert "unknown metrics snapshot schema" in r.stderr


def test_obs_report_renders_bundle(tmp_path):
    obs.enable()
    _online()
    trace_p = obs.tracer().save(tmp_path / "trace.json")
    metrics_p = obs.metrics_registry().save(tmp_path / "metrics.json")
    obs.disable()
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"),
         "--trace", str(trace_p), "--decisions",
         "--metrics", str(metrics_p)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "decision audit trail" in out.stdout
    assert "engine_dispatches" in out.stdout
    # invalid input exits 2
    bad = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"),
         "--trace", str(metrics_p)], capture_output=True, text=True)
    assert bad.returncode == 2
