"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU; asserts output shapes and absence of NaNs.  Also checks
decode-vs-forward consistency for a few representative families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model

ARCHS = sorted(configs.ALL_ARCHS)


def _batch(cfg, rng, batch=2, seq=16):
    r1, r2, r3, r4 = jax.random.split(rng, 4)
    b = {
        "tokens": jax.random.randint(r1, (batch, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(r2, (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        b["frame_embeds"] = jax.random.normal(r3, (batch, 8, cfg.d_model))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                               (3, batch, seq))
        b["positions"] = pos
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(r4, (batch, 4, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN in logits"

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: model.loss(p, b)))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gnorm = jax.tree.reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))),
        grads, jnp.float32(0.0))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"

    # one SGD step reduces nothing catastrophic (params stay finite)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2 = jax.jit(lambda p, b: model.loss(p, b))(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma3-27b", "mamba2-780m",
                                  "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-medium"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1), batch=1, seq=8)
    tokens = batch["tokens"]

    full = model.forward(params, batch)  # (1, 8, V)

    caches = model.init_caches(batch_size=1, max_len=16)
    if cfg.is_encdec:
        caches["enc_out"] = model._encode(params, batch)
    step = jax.jit(model.decode_step)
    for t in range(8):
        logits, caches = step(params, tokens[:, t], caches,
                              jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits[0]), np.asarray(full[0, t]),
            rtol=2e-2, atol=2e-2,
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_estimate_close(arch):
    """ArchConfig.param_count must track actual init sizes on reduced cfgs
    (within 20% — the estimator is used for roofline MODEL_FLOPS)."""
    cfg = configs.get(arch).reduced()
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    est, _ = cfg.param_count()
    assert abs(actual - est) / actual < 0.25, (arch, actual, est)
