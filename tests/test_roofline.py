"""Validate the trip-count-aware HLO cost analyzer against known modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost
from repro.roofline.analysis import collective_bytes
from repro.roofline.hw import roofline_terms


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = _compiled_text(lambda a, b: a @ b, x, x)
    cost = hlo_cost.analyze(txt)
    assert cost.flops == 2 * 256 ** 3


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    txt = _compiled_text(scanned, x, ws)
    cost = hlo_cost.analyze(txt)
    expected = 7 * 2 * 128 ** 3
    # XLA may add trivial flops; the dot count must match exactly-ish
    assert abs(cost.flops - expected) / expected < 0.01, cost.flops


def test_nested_scan():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)

    def nested(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    txt = _compiled_text(nested, x, ws)
    cost = hlo_cost.analyze(txt)
    expected = 3 * 5 * 2 * 64 ** 3
    assert abs(cost.flops - expected) / expected < 0.01, cost.flops


def test_bytes_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

    def loop(x):
        def body(c, _):
            return c * 2.0 + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=11)
        return y

    txt = _compiled_text(loop, x)
    cost = hlo_cost.analyze(txt)
    # each iteration touches >= in+out of the (1024,1024) f32 buffer
    assert cost.bytes >= 11 * 2 * 4 * 1024 * 1024


def test_collective_regex_on_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p0: f32[1024,256]) -> f32[1024,256] {
  %p0 = f32[1024,256]{1,0} parameter(0)
  %ar = f32[1024,256]{1,0} all-reduce(f32[1024,256]{1,0} %p0), replica_groups={}
  ROOT %copy = f32[1024,256]{1,0} copy(f32[1024,256]{1,0} %ar)
}
"""
    total, by_kind = collective_bytes(hlo)
    assert total == 1024 * 256 * 4
    assert by_kind == {"all-reduce": 1024 * 256 * 4}
    cost = hlo_cost.analyze(hlo)
    assert cost.collective_bytes == 1024 * 256 * 4


def test_roofline_terms_dominance():
    t = roofline_terms(flops=197e12 * 256, bytes_hbm=1.0,
                       bytes_collective=1.0, chips=256)
    assert t["dominant"] == "compute" and abs(t["t_compute_s"] - 1.0) < 1e-9
    t = roofline_terms(flops=1.0, bytes_hbm=819e9 * 256,
                       bytes_collective=1.0, chips=256)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops=1.0, bytes_hbm=1.0,
                       bytes_collective=50e9 * 4 * 256, chips=256)
    assert t["dominant"] == "collective"
