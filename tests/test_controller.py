"""Integration tests for the Morpheus controller state machine."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import address_separation as asep
from repro.core import controller as ctl


def _cfg(conv_sets=8, chips=2, sets_per_chip=4, **kw):
    amap = asep.make_map(conv_sets=conv_sets, num_cache_chips=chips,
                         sets_per_chip=sets_per_chip)
    return ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4, **kw)


def _sim(cfg, addrs, writes=None, levels=None):
    addrs = np.asarray(addrs, np.uint32)
    writes = np.zeros(len(addrs), bool) if writes is None else np.asarray(writes)
    levels = np.full(len(addrs), 2, np.int32) if levels is None else np.asarray(levels)
    return ctl.simulate(cfg, jnp.asarray(addrs), jnp.asarray(writes),
                        jnp.asarray(levels))


def test_conventional_only_no_ext_traffic():
    amap = asep.make_map(conv_sets=8, num_cache_chips=0, sets_per_chip=0)
    cfg = ctl.MorpheusConfig(amap=amap, conv_ways=4, ext_ways=4)
    stats = _sim(cfg, [0, 16, 0, 16, 0])  # both map to conventional sets
    assert int(stats.ext_hits + stats.ext_true_miss) == 0
    assert float(stats.noc_bytes) == 0.0
    assert int(stats.conv_hits) == 3 and int(stats.conv_misses) == 2


def test_repeat_access_hits_in_each_tier():
    cfg = _cfg()
    # total_sets = 8 + 8 = 16; addr 0 -> set 0 (conv); addr 8 -> set 8 (ext)
    stats = _sim(cfg, [0, 0, 0, 8, 8, 8])
    assert int(stats.conv_hits) == 2 and int(stats.conv_misses) == 1
    assert int(stats.ext_hits) == 2 and int(stats.ext_true_miss) == 1
    # first ext access: empty BF1 -> predicted miss (not a false positive)
    assert int(stats.ext_pred_miss) == 1
    assert int(stats.ext_false_pos) == 0


def test_bloom_never_false_negative_vs_perfect():
    """BLOOM must forward (at least) every request PERFECT forwards: its
    ext_hits equals PERFECT's ext_hits on any trace."""
    rng = np.random.default_rng(3)
    addrs = rng.integers(0, 256, size=1500).astype(np.uint32)
    base = _cfg(conv_sets=8, chips=2, sets_per_chip=4)
    s_bloom = _sim(base, addrs)
    s_perfect = _sim(_cfg(predictor=ctl.Predictor.PERFECT), addrs)
    assert int(s_bloom.ext_hits) == int(s_perfect.ext_hits)
    assert int(s_perfect.ext_false_pos) == 0


def test_no_prediction_forwards_everything():
    rng = np.random.default_rng(4)
    addrs = rng.integers(0, 256, size=800).astype(np.uint32)
    s_none = _sim(_cfg(predictor=ctl.Predictor.NONE), addrs)
    assert int(s_none.ext_pred_miss) == 0
    # every miss is a (costly) forwarded miss
    assert int(s_none.ext_false_pos) == int(s_none.ext_true_miss)


def test_predictor_latency_ordering():
    """Fig. 13: Perfect <= Bloom <= No-Prediction in total latency."""
    rng = np.random.default_rng(5)
    addrs = rng.integers(0, 512, size=3000).astype(np.uint32)
    lat = {}
    for p in ctl.Predictor:
        lat[p] = float(_sim(_cfg(predictor=p), addrs).latency_ns)
    assert lat[ctl.Predictor.PERFECT] <= lat[ctl.Predictor.BLOOM] + 1e-3
    assert lat[ctl.Predictor.BLOOM] <= lat[ctl.Predictor.NONE] + 1e-3


def test_compression_increases_ext_hits():
    """Zipf-ish trace with highly compressible blocks: compression must not
    reduce (and normally increases) extended-tier hits."""
    rng = np.random.default_rng(6)
    u = rng.random(6000)
    addrs = ((u ** 2.0) * 1024).astype(np.uint32)
    levels = np.zeros(len(addrs), np.int32)  # all HIGH-compressible
    s_off = _sim(_cfg(), addrs, levels=levels)
    s_on = _sim(_cfg(compression=True), addrs, levels=levels)
    assert int(s_on.ext_hits) >= int(s_off.ext_hits)


def test_indirect_mov_reduces_latency():
    rng = np.random.default_rng(7)
    addrs = rng.integers(0, 128, size=2000).astype(np.uint32)
    s_base = _sim(_cfg(), addrs)
    s_imov = _sim(_cfg(indirect_mov=True), addrs)
    assert int(s_imov.ext_hits) == int(s_base.ext_hits)   # same behaviour
    assert float(s_imov.latency_ns) < float(s_base.latency_ns)


def test_writeback_accounting():
    cfg = _cfg(conv_sets=1, chips=1, sets_per_chip=1)  # tiny: 1 conv, 1 ext set
    # conv set: ways=4; write 5 distinct conv-mapped blocks (set 0 of 2 total)
    addrs = [0, 2, 4, 6, 8]  # even -> set 0 (conv), total_sets=2
    stats = _sim(cfg, addrs, writes=[True] * 5)
    assert int(stats.writebacks) == 1  # 5th insert evicts a dirty block


def test_stats_conservation():
    """Every request is accounted in exactly one outcome bucket."""
    rng = np.random.default_rng(8)
    addrs = rng.integers(0, 4096, size=4000).astype(np.uint32)
    s = _sim(_cfg(conv_sets=32, chips=4, sets_per_chip=8), addrs)
    total = (int(s.conv_hits) + int(s.conv_misses) + int(s.ext_hits)
             + int(s.ext_true_miss))
    assert total == 4000
    assert int(s.ext_true_miss) == int(s.ext_false_pos) + int(s.ext_pred_miss)
